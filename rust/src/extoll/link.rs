//! Link-level reliability protocol (`reliability=link`, docs/ARCHITECTURE.md §6).
//!
//! Extoll's link layer is what makes the fabric *reliable*: every packet
//! crossing a cable is CRC-protected, and a corrupted packet is replayed
//! from the sender's retransmission buffer rather than surfacing as loss
//! (the source paper picks Extoll for exactly this property). PR 6's fault
//! model turned CRC failure into *silent* receiver-side drops; this module
//! adds the recovery protocol on top:
//!
//! - **Per-link sequence numbers** — the transmitter of each torus port
//!   stamps outgoing packets with a monotone sequence (`Packet::link_seq`,
//!   `0` = unstamped); the receiver tracks the next expected sequence per
//!   upstream `(actor, port)` link.
//! - **Cumulative ACK / NACK** — an in-order arrival is acknowledged
//!   cumulatively (`Msg::Ack { ack }` ⇒ everything below `ack` arrived); a
//!   CRC failure or a sequence gap requests a go-back-N replay
//!   (`Msg::Nack { expect }`). Control frames are modeled like credit
//!   flits: they cross the reverse link in exactly
//!   [`super::nic::NicConfig::credit_return_latency`] and occupy no input
//!   buffer, so they can neither be lost nor deadlock (§6 in the
//!   architecture book for the full argument).
//! - **Bounded retransmission buffer** — at most
//!   [`LinkReliabilityConfig::window`] unacknowledged packets per link;
//!   fresh transmissions stall (like a credit stall) while the window is
//!   full, retransmissions always pass.
//! - **Timeout + exponential backoff** — a per-port retransmission timer
//!   (an ordinary intra-node `send_self` event, so it composes with the
//!   partitioned PDES) replays the buffer when no ACK/NACK shows progress
//!   for `timeout << backoff`; the backoff shift grows per consecutive
//!   timeout up to [`LinkReliabilityConfig::backoff_cap`] and resets on any
//!   progress.
//! - **Retry budget** — an entry that survives
//!   [`LinkReliabilityConfig::max_retries`] replay rounds is abandoned:
//!   accounted as undeliverable + residual loss (never silently dropped),
//!   and the receiver's expectation is advanced past the abandoned prefix
//!   via `Msg::SeqSkip` so the link keeps making progress.
//!
//! All state transitions are pure functions of the owning NIC's event
//! order, which the engine keeps partition-independent (merge-key
//! contract) — so `reliability=link` runs are byte-identical across
//! `domains`, `sync` modes, queue backends and `--jobs`, and
//! `reliability=off` instantiates none of this (the NIC holds no
//! [`LinkLayer`] at all), staying byte-identical to the pre-reliability
//! fabric. Gated in `rust/tests/determinism_queue.rs`.

use std::collections::{BTreeMap, VecDeque};

use crate::sim::{ActorId, Time};

use super::packet::Packet;
use super::torus::TORUS_PORTS;

/// The `reliability=` experiment knob: which recovery layer runs on the
/// torus links.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Reliability {
    /// No link-layer recovery — CRC failures are silent loss (PR 6
    /// behavior, byte-identical to the pre-reliability fabric).
    #[default]
    Off,
    /// Per-link ACK/NACK retransmission with timeout + backoff.
    Link,
}

impl Reliability {
    /// Parse the knob value (`off` | `link`).
    pub fn parse(s: &str) -> Option<Reliability> {
        match s {
            "off" => Some(Reliability::Off),
            "link" => Some(Reliability::Link),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Reliability::Off => "off",
            Reliability::Link => "link",
        }
    }
}

/// Tuning knobs of the link reliability protocol (`docs/TUNING.md`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkReliabilityConfig {
    /// Max unacknowledged packets in flight per link; a full window stalls
    /// fresh transmissions (retransmissions always pass) until an ACK.
    pub window: u32,
    /// Base retransmission timeout: a replay fires when no ACK/NACK shows
    /// progress on a port for this long (well above the ~195 ns healthy
    /// data+ACK round trip, so NACKs — not timeouts — drive recovery on a
    /// live link and the timer stays a backstop).
    pub timeout: Time,
    /// Replay rounds an entry may survive before it is abandoned
    /// (undeliverable + residual loss).
    pub max_retries: u32,
    /// Cap on the exponential-backoff shift: the timeout grows as
    /// `timeout << min(consecutive_timeouts, backoff_cap)`.
    pub backoff_cap: u32,
}

impl Default for LinkReliabilityConfig {
    fn default() -> Self {
        LinkReliabilityConfig {
            window: 32,
            timeout: Time::from_us(2),
            max_retries: 16,
            backoff_cap: 6,
        }
    }
}

impl LinkReliabilityConfig {
    /// The retransmission timeout after `backoff` consecutive timeouts
    /// (exponential, capped; the shift is additionally clamped so the
    /// arithmetic can never overflow).
    pub fn timeout_after(&self, backoff: u32) -> Time {
        let shift = backoff.min(self.backoff_cap).min(20);
        Time::from_ps(self.timeout.ps().saturating_mul(1u64 << shift))
    }
}

/// One transmitted-but-unacknowledged packet in a [`TxLink`] buffer.
#[derive(Debug)]
pub(crate) struct InFlight {
    /// Link sequence stamped at first transmission.
    pub seq: u64,
    /// Retransmission copy (`ingress` cleared — the copy never owes an
    /// upstream credit; `hops` frozen at the first transmission, a replay
    /// crosses the same cable and adds no topological hop).
    pub packet: Packet,
    /// When the first transmission started (recovery-latency accounting).
    pub first_tx: Time,
    /// Replay rounds survived so far.
    pub retries: u32,
    /// A retransmission copy currently sits in the egress queue, so a
    /// replay must not enqueue another one.
    pub queued: bool,
}

/// An entry retired by a cumulative ACK after at least one retransmission
/// — the link layer *recovered* it.
pub(crate) struct Recovered {
    /// Spike events the packet carried.
    pub events: u64,
    /// First-transmission instant (recovery latency = ack time − this).
    pub first_tx: Time,
}

/// What a go-back-N replay round decided (the caller turns this into
/// queue pushes, stats and the `SeqSkip` control frame).
pub(crate) struct ReplayOutcome {
    /// Retransmission copies to queue, ascending sequence order.
    pub clones: Vec<Packet>,
    /// Packets abandoned this round (retry budget exhausted).
    pub residual_packets: u64,
    /// Spike events inside the abandoned packets.
    pub residual_events: u64,
    /// When `residual_packets > 0`: the receiver must skip forward to
    /// expect this sequence (first surviving entry, or one past the last
    /// stamped sequence when the buffer drained).
    pub skip_to: u64,
}

/// Sender-side reliability state of one torus port (one directed link).
#[derive(Debug, Default)]
pub(crate) struct TxLink {
    /// Last stamped sequence (first real sequence is 1; 0 marks an
    /// unstamped packet).
    last_seq: u64,
    /// Unacknowledged packets, ascending sequence.
    inflight: VecDeque<InFlight>,
    /// Consecutive timeouts without progress (exponential-backoff shift).
    pub backoff: u32,
    /// A retransmission timer event is outstanding for this port.
    pub timer_outstanding: bool,
    /// Last instant the link showed life (transmission or control frame)
    /// — the timer replays only when `timeout_after(backoff)` passes
    /// without this advancing.
    pub last_progress: Time,
    /// NACK base we already replayed for — duplicate NACKs of the same
    /// loss (one per gap arrival) must not trigger duplicate replays.
    /// Cleared on progress; a repeat loss of the same retransmission is
    /// recovered by the timeout backstop.
    pub replayed_for: Option<u64>,
}

impl TxLink {
    /// Stamp the next fresh packet.
    pub fn stamp(&mut self) -> u64 {
        self.last_seq += 1;
        self.last_seq
    }

    /// Record a freshly transmitted packet in the retransmission buffer.
    pub fn record(&mut self, seq: u64, packet: Packet, now: Time) {
        debug_assert!(self.inflight.back().is_none_or(|e| e.seq < seq));
        self.inflight.push_back(InFlight {
            seq,
            packet,
            first_tx: now,
            retries: 0,
            queued: false,
        });
    }

    /// A retransmission copy for `seq` left the egress queue.
    pub fn mark_sent(&mut self, seq: u64) {
        if let Some(e) = self.inflight.iter_mut().find(|e| e.seq == seq) {
            e.queued = false;
        }
    }

    pub fn window_full(&self, window: u32) -> bool {
        self.inflight.len() >= window as usize
    }

    pub fn is_empty(&self) -> bool {
        self.inflight.is_empty()
    }

    /// Cumulative acknowledgement: retire every entry below `upto`,
    /// appending the ones that needed retransmission to `recovered`.
    /// Returns whether anything was retired.
    pub fn ack_advance(&mut self, upto: u64, recovered: &mut Vec<Recovered>) -> bool {
        let mut progressed = false;
        while let Some(e) = self.inflight.front() {
            if e.seq >= upto {
                break;
            }
            let e = self.inflight.pop_front().unwrap();
            progressed = true;
            if e.retries > 0 {
                recovered.push(Recovered {
                    events: e.packet.n_events() as u64,
                    first_tx: e.first_tx,
                });
            }
        }
        progressed
    }

    /// One go-back-N replay round: every entry ages by one retry; entries
    /// beyond `max_retries` are abandoned (they form a prefix — entries
    /// age together, so older ones always have at least as many retries),
    /// the rest are re-queued unless a copy is already queued.
    pub fn replay(&mut self, max_retries: u32) -> ReplayOutcome {
        let mut out = ReplayOutcome {
            clones: Vec::new(),
            residual_packets: 0,
            residual_events: 0,
            skip_to: 0,
        };
        let mut kept = VecDeque::with_capacity(self.inflight.len());
        while let Some(mut e) = self.inflight.pop_front() {
            e.retries += 1;
            if e.retries > max_retries {
                out.residual_packets += 1;
                out.residual_events += e.packet.n_events() as u64;
                continue;
            }
            if !e.queued {
                e.queued = true;
                out.clones.push(e.packet.clone());
            }
            kept.push_back(e);
        }
        self.inflight = kept;
        out.skip_to = match self.inflight.front() {
            Some(e) => e.seq,
            None => self.last_seq + 1,
        };
        out
    }
}

/// The whole per-NIC reliability state: one [`TxLink`] per torus port plus
/// the receiver's next-expected sequence per upstream link. Instantiated
/// only under `reliability=link` — an `off` NIC carries `None` and runs
/// the exact pre-reliability code paths.
#[derive(Debug)]
pub struct LinkLayer {
    pub cfg: LinkReliabilityConfig,
    pub(crate) tx: [TxLink; TORUS_PORTS as usize],
    /// Next expected sequence per upstream directed link, keyed by the
    /// *sender's* `(actor, port)` — unambiguous even on 2-rings where one
    /// neighbor reaches us over two different cables. `BTreeMap` for
    /// deterministic state independent of actor-id magnitudes.
    rx: BTreeMap<(ActorId, u8), u64>,
}

impl LinkLayer {
    pub fn new(cfg: LinkReliabilityConfig) -> Self {
        LinkLayer {
            cfg,
            tx: std::array::from_fn(|_| TxLink::default()),
            rx: BTreeMap::new(),
        }
    }

    /// The receiver's next expected sequence from upstream `(actor,
    /// port)`; sequences start at 1.
    pub(crate) fn rx_expect(&mut self, from: ActorId, port: u8) -> &mut u64 {
        self.rx.entry((from, port)).or_insert(1)
    }

    /// The upstream sender abandoned everything below `expect` — stop
    /// waiting for it (monotone: a stale skip never rewinds).
    pub(crate) fn rx_skip(&mut self, from: ActorId, port: u8, expect: u64) {
        let e = self.rx.entry((from, port)).or_insert(1);
        *e = (*e).max(expect);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::torus::NodeAddr;

    fn pkt(seq: u64) -> Packet {
        Packet::raw(NodeAddr(0), NodeAddr(1), 64, Time::ZERO, seq)
    }

    #[test]
    fn knob_parses_and_roundtrips() {
        assert_eq!(Reliability::parse("off"), Some(Reliability::Off));
        assert_eq!(Reliability::parse("link"), Some(Reliability::Link));
        assert_eq!(Reliability::parse("tcp"), None);
        assert_eq!(Reliability::default(), Reliability::Off);
        for r in [Reliability::Off, Reliability::Link] {
            assert_eq!(Reliability::parse(r.as_str()), Some(r));
        }
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let cfg = LinkReliabilityConfig::default();
        assert_eq!(cfg.timeout_after(0), cfg.timeout);
        assert_eq!(cfg.timeout_after(3), Time::from_ps(cfg.timeout.ps() << 3));
        assert_eq!(
            cfg.timeout_after(99),
            Time::from_ps(cfg.timeout.ps() << cfg.backoff_cap)
        );
        // pathological user caps must not overflow the shift
        let wild = LinkReliabilityConfig {
            backoff_cap: 4000,
            ..LinkReliabilityConfig::default()
        };
        assert!(wild.timeout_after(5000) > Time::ZERO);
    }

    #[test]
    fn stamps_are_monotone_from_one() {
        let mut tx = TxLink::default();
        assert_eq!(tx.stamp(), 1);
        assert_eq!(tx.stamp(), 2);
        assert_eq!(tx.stamp(), 3);
    }

    #[test]
    fn cumulative_ack_retires_prefix_and_reports_recoveries() {
        let mut tx = TxLink::default();
        for s in 1..=4u64 {
            let seq = tx.stamp();
            assert_eq!(seq, s);
            tx.record(seq, pkt(seq), Time::from_ns(s * 10));
        }
        // age everything once so retirements count as recoveries
        let out = tx.replay(16);
        assert_eq!(out.clones.len(), 4);
        assert_eq!(out.residual_packets, 0);
        let mut rec = Vec::new();
        assert!(tx.ack_advance(3, &mut rec));
        assert_eq!(rec.len(), 2, "seq 1 and 2 retired after a retry");
        assert_eq!(rec[0].first_tx, Time::from_ns(10));
        assert!(!tx.ack_advance(3, &mut rec), "no further progress at the same ack");
        assert!(!tx.is_empty());
        assert!(tx.ack_advance(5, &mut rec));
        assert!(tx.is_empty());
    }

    #[test]
    fn window_bounds_the_buffer() {
        let mut tx = TxLink::default();
        for _ in 0..3 {
            let seq = tx.stamp();
            tx.record(seq, pkt(seq), Time::ZERO);
        }
        assert!(!tx.window_full(4));
        assert!(tx.window_full(3));
    }

    #[test]
    fn replay_abandons_exactly_the_over_budget_prefix() {
        let mut tx = TxLink::default();
        for _ in 0..2 {
            let seq = tx.stamp();
            tx.record(seq, pkt(seq), Time::ZERO);
        }
        let out = tx.replay(1); // retries: 1,1 — both survive
        assert_eq!(out.clones.len(), 2);
        assert_eq!(out.residual_packets, 0);
        // a younger entry joins before the next round
        let seq = tx.stamp();
        tx.record(seq, pkt(seq), Time::ZERO);
        let out = tx.replay(1); // retries: 2,2,1 — the old pair is abandoned
        assert_eq!(out.residual_packets, 2);
        assert_eq!(out.skip_to, 3, "receiver must skip to the first survivor");
        // the survivor already has a queued copy from its first round
        assert_eq!(out.clones.len(), 1);
        let out = tx.replay(1);
        assert_eq!(out.residual_packets, 1);
        assert!(tx.is_empty());
        assert_eq!(out.skip_to, 4, "drained buffer skips past the last stamp");
    }

    #[test]
    fn mark_sent_allows_the_next_replay_to_clone_again() {
        let mut tx = TxLink::default();
        let seq = tx.stamp();
        tx.record(seq, pkt(seq), Time::ZERO);
        assert_eq!(tx.replay(16).clones.len(), 1);
        assert_eq!(tx.replay(16).clones.len(), 0, "copy still queued");
        tx.mark_sent(seq);
        assert_eq!(tx.replay(16).clones.len(), 1);
    }

    #[test]
    fn rx_expect_is_per_link_and_skip_is_monotone() {
        let mut l = LinkLayer::new(LinkReliabilityConfig::default());
        assert_eq!(*l.rx_expect(7, 0), 1);
        *l.rx_expect(7, 0) = 5;
        assert_eq!(*l.rx_expect(7, 1), 1, "ports are independent links");
        assert_eq!(*l.rx_expect(8, 0), 1, "actors are independent links");
        l.rx_skip(7, 0, 9);
        assert_eq!(*l.rx_expect(7, 0), 9);
        l.rx_skip(7, 0, 2);
        assert_eq!(*l.rx_expect(7, 0), 9, "skip never rewinds");
    }
}
