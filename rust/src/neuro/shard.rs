//! One neuron shard: AOT-compiled LIF dynamics + its spike I/O mapping.
//!
//! `ShardSim` owns the packed state of the neurons behind one FPGA, the
//! shard's weight matrix, and a handle to the compiled step executable.
//! The coordinator calls [`ShardSim::step`] once per timestep with the
//! global spike-count vector assembled from the events the simulated
//! Extoll fabric delivered, and receives the local spike indices to feed
//! back into the fabric.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{ShardModel, WeightBuffer};
use crate::sim::{F32Arena, F32Handle};

/// Mapping local neuron index → (HICANN link, pulse address). The 8
/// HICANNs of an FPGA interleave across the shard.
pub fn pulse_of_neuron(local: u32) -> (u8, u16) {
    ((local & 7) as u8, (local >> 3) as u16)
}

/// Inverse of [`pulse_of_neuron`].
pub fn neuron_of_pulse(hicann: u8, pulse: u16) -> u32 {
    ((pulse as u32) << 3) | hicann as u32
}

/// Step-invariant weights: retained by the runtime when the upload
/// succeeds, host-resident fallback otherwise — exactly one copy of the
/// n_local×n_global matrix either way.
enum Weights {
    Uploaded(WeightBuffer),
    Host(Vec<f32>),
}

/// A live shard: state + weights + compiled step.
pub struct ShardSim {
    model: ShardModel,
    /// Packed `[3, n_local]` state.
    state: Vec<f32>,
    weights: Weights,
    /// Global index of this shard's first neuron.
    pub global_base: u32,
    /// Spikes emitted in the most recent step (local indices).
    pub last_spikes: Vec<u32>,
    /// Total spikes so far.
    pub total_spikes: u64,
    pub steps: u64,
}

impl ShardSim {
    pub fn new(model: ShardModel, weights: Vec<f32>, global_base: u32) -> Self {
        let n_local = model.n_local();
        assert_eq!(weights.len(), n_local * model.n_global());
        let weights = match model.upload_weights(&weights) {
            Ok(buf) => Weights::Uploaded(buf),
            Err(_) => Weights::Host(weights),
        };
        ShardSim {
            model,
            state: vec![0.0; 3 * n_local],
            weights,
            global_base,
            last_spikes: Vec::new(),
            total_spikes: 0,
            steps: 0,
        }
    }

    pub fn n_local(&self) -> usize {
        self.model.n_local()
    }

    pub fn n_global(&self) -> usize {
        self.model.n_global()
    }

    /// Randomize initial membrane potentials in `[lo, hi)` to desynchronize
    /// the network (all-zero init makes every neuron fire in lockstep).
    pub fn randomize_v(&mut self, rng: &mut crate::util::rng::Rng, lo: f32, hi: f32) {
        let n = self.n_local();
        for v in &mut self.state[..n] {
            *v = lo + (hi - lo) * rng.f64() as f32;
        }
    }

    /// Advance one timestep given the global spike-count vector; records
    /// and returns the local indices that spiked.
    pub fn step(&mut self, spikes_global: &[f32]) -> Result<&[u32]> {
        let out = match &self.weights {
            Weights::Uploaded(buf) => self.model.step_with(&self.state, spikes_global, buf)?,
            Weights::Host(w) => self.model.step(&self.state, spikes_global, w)?,
        };
        self.state = out;
        let n = self.n_local();
        self.last_spikes.clear();
        let spikes = ShardModel::spikes_of(&self.state, n);
        for (i, &s) in spikes.iter().enumerate() {
            if s > 0.0 {
                self.last_spikes.push(i as u32);
            }
        }
        self.total_spikes += self.last_spikes.len() as u64;
        self.steps += 1;
        Ok(&self.last_spikes)
    }

    /// Mean firing rate in spikes/neuron/step.
    pub fn mean_rate(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.total_spikes as f64 / (self.steps as f64 * self.n_local() as f64)
    }

    /// Membrane potential of neuron `i` (diagnostics).
    pub fn v(&self, i: usize) -> f32 {
        self.state[i]
    }
}

/// All shards of one rack-scale run in structure-of-arrays layout: one
/// contiguous membrane-state block for every shard, weight matrices read
/// straight out of the shared prepared [`F32Arena`] (no per-execute
/// copy), and per-shard spike bookkeeping in flat vectors.
///
/// This replaces a `Vec<ShardSim>` on the microcircuit path. Per-shard
/// heap boxes made a 20-wafer rack (~10⁵ neurons, ~10⁸ synapses) both
/// oversized — `ShardSim::new` duplicated each weight matrix into the
/// runtime — and cache-hostile. Physics are bit-identical to `ShardSim`:
/// the same [`ShardModel`] step executes against the same weight bytes,
/// only their storage differs.
pub struct ShardArena {
    model: ShardModel,
    /// Shared immutable weights (owned by the scenario's `Prepared`).
    weights: Arc<F32Arena>,
    /// Per-shard weight rows inside `weights`.
    weight_rows: Vec<F32Handle>,
    /// Packed membrane state: shard `f` owns
    /// `state[f * 3 * n_local .. (f + 1) * 3 * n_local]`.
    state: Vec<f32>,
    /// Spikes emitted by each shard in its most recent step.
    last_spikes: Vec<Vec<u32>>,
    /// Total spikes per shard.
    total_spikes: Vec<u64>,
    /// Steps advanced per shard.
    steps: Vec<u64>,
}

impl ShardArena {
    /// `weight_rows[f]` must be an `[n_local, n_global]` matrix for every
    /// shard `f`.
    pub fn new(model: ShardModel, weights: Arc<F32Arena>, weight_rows: Vec<F32Handle>) -> Self {
        let n_local = model.n_local();
        let n_global = model.n_global();
        for row in &weight_rows {
            assert_eq!(row.len(), n_local * n_global, "weight row shape");
        }
        let n_shards = weight_rows.len();
        ShardArena {
            model,
            weights,
            weight_rows,
            state: vec![0.0; n_shards * 3 * n_local],
            last_spikes: vec![Vec::new(); n_shards],
            total_spikes: vec![0; n_shards],
            steps: vec![0; n_shards],
        }
    }

    pub fn n_shards(&self) -> usize {
        self.weight_rows.len()
    }

    pub fn n_local(&self) -> usize {
        self.model.n_local()
    }

    pub fn n_global(&self) -> usize {
        self.model.n_global()
    }

    fn state_range(&self, f: usize) -> std::ops::Range<usize> {
        let block = 3 * self.model.n_local();
        f * block..(f + 1) * block
    }

    /// Randomize initial membrane potentials of every shard in `[lo, hi)`,
    /// shard-major — the identical draw order to looping
    /// [`ShardSim::randomize_v`] over shards with one RNG.
    pub fn randomize_v(&mut self, rng: &mut crate::util::rng::Rng, lo: f32, hi: f32) {
        let n = self.model.n_local();
        for f in 0..self.n_shards() {
            let r = self.state_range(f);
            for v in &mut self.state[r][..n] {
                *v = lo + (hi - lo) * rng.f64() as f32;
            }
        }
    }

    /// Advance shard `f` one timestep given the global spike-count vector;
    /// records and returns the local indices that spiked.
    pub fn step_shard(&mut self, f: usize, spikes_global: &[f32]) -> Result<&[u32]> {
        let w = self.weights.row(self.weight_rows[f]);
        let r = self.state_range(f);
        let out = self.model.step(&self.state[r.clone()], spikes_global, w)?;
        self.state[r.clone()].copy_from_slice(&out);
        let n = self.model.n_local();
        let spikes = ShardModel::spikes_of(&self.state[r], n);
        self.last_spikes[f].clear();
        for (i, &s) in spikes.iter().enumerate() {
            if s > 0.0 {
                self.last_spikes[f].push(i as u32);
            }
        }
        self.total_spikes[f] += self.last_spikes[f].len() as u64;
        self.steps[f] += 1;
        Ok(&self.last_spikes[f])
    }

    /// Spikes shard `f` emitted in its most recent step.
    pub fn last_spikes(&self, f: usize) -> &[u32] {
        &self.last_spikes[f]
    }

    /// Total spikes across all shards.
    pub fn total_spikes(&self) -> u64 {
        self.total_spikes.iter().sum()
    }

    /// Membrane potential of neuron `i` of shard `f` (diagnostics).
    pub fn v(&self, f: usize, i: usize) -> f32 {
        self.state[self.state_range(f)][i]
    }

    /// Restore the arena to its just-constructed state (the neuron-layer
    /// analogue of `Sim::reset_to_epoch`): zero state and counters, keep
    /// the shared weights and every handle valid.
    pub fn reset_state(&mut self) {
        self.state.fill(0.0);
        for s in &mut self.last_spikes {
            s.clear();
        }
        self.total_spikes.fill(0);
        self.steps.fill(0);
    }

    /// Heap bytes of the per-run state (the shared weight arena is
    /// accounted by its owner, the scenario's `Prepared`).
    pub fn resident_bytes(&self) -> usize {
        self.state.capacity() * std::mem::size_of::<f32>()
            + self
                .last_spikes
                .iter()
                .map(|s| s.capacity() * std::mem::size_of::<u32>())
                .sum::<usize>()
            + self.total_spikes.capacity() * 8
            + self.steps.capacity() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir, Runtime};

    #[test]
    fn pulse_mapping_roundtrip() {
        for local in [0u32, 1, 7, 8, 255, 1023, 4095] {
            let (h, p) = pulse_of_neuron(local);
            assert!(h < 8);
            assert!(p < (1 << 12));
            assert_eq!(neuron_of_pulse(h, p), local);
        }
    }

    fn shard_manifest(rt: &Runtime) -> crate::runtime::Manifest {
        rt.load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap()
            .manifest
    }

    #[test]
    fn shard_steps_and_counts_spikes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt
            .load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        // zero weights: dynamics driven only by the baked-in i_ext; over
        // 50 steps the membrane follows v = i_ext·(1 - decay^k), still
        // below threshold → no spikes yet
        let (i_ext, decay) = {
            let m = &shard_manifest(&rt);
            (m.i_ext, m.decay)
        };
        let mut shard = ShardSim::new(model, vec![0.0; n_local * n_global], 0);
        let spikes_in = vec![0.0f32; n_global];
        for _ in 0..50 {
            let s = shard.step(&spikes_in).unwrap();
            assert!(s.is_empty());
        }
        assert_eq!(shard.total_spikes, 0);
        assert_eq!(shard.steps, 50);
        let expect = (i_ext * (1.0 - decay.powi(50))) as f32;
        assert!(
            (shard.v(0) - expect).abs() < 1e-3,
            "v={} expect={expect}",
            shard.v(0)
        );
    }

    #[test]
    fn arena_matches_shardsim_bit_for_bit() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt
            .load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        let n_shards = n_global / n_local;
        // deterministic sparse weights, one matrix per shard
        let mut arena = F32Arena::new();
        let mut rows = Vec::new();
        let mut boxed = Vec::new();
        for f in 0..n_shards {
            let mut w = vec![0.0f32; n_local * n_global];
            for i in (f..w.len()).step_by(97) {
                w[i] = if i % 2 == 0 { 40.0 } else { -40.0 };
            }
            rows.push(arena.alloc_with(w.len(), |row| row.copy_from_slice(&w)));
            boxed.push(ShardSim::new(model.clone(), w, (f * n_local) as u32));
        }
        let mut soa = ShardArena::new(model, Arc::new(arena), rows);
        assert_eq!(soa.n_shards(), n_shards);
        // identical init draws
        let mut r1 = crate::util::rng::Rng::new(0xB55);
        let mut r2 = crate::util::rng::Rng::new(0xB55);
        for s in &mut boxed {
            s.randomize_v(&mut r1, -0.5, 0.9);
        }
        soa.randomize_v(&mut r2, -0.5, 0.9);
        // drive both with the same inputs for a few steps
        let mut spikes_in = vec![0.0f32; n_global];
        for k in 0..20 {
            spikes_in.iter_mut().for_each(|x| *x = 0.0);
            spikes_in[(k * 13) % n_global] = 1.0;
            for (f, s) in boxed.iter_mut().enumerate() {
                let a = s.step(&spikes_in).unwrap().to_vec();
                let b = soa.step_shard(f, &spikes_in).unwrap();
                assert_eq!(a.as_slice(), b, "step {k} shard {f}");
            }
        }
        assert_eq!(
            soa.total_spikes(),
            boxed.iter().map(|s| s.total_spikes).sum::<u64>()
        );
        for (f, s) in boxed.iter().enumerate() {
            for i in [0usize, 1, n_local - 1] {
                assert_eq!(soa.v(f, i), s.v(i), "membrane shard {f} neuron {i}");
            }
        }
        assert!(soa.resident_bytes() >= n_shards * 3 * n_local * 4);
        // reset restores the just-constructed state; handles stay valid
        soa.reset_state();
        assert_eq!(soa.total_spikes(), 0);
        assert_eq!(soa.v(0, 0), 0.0);
        let fresh = soa.step_shard(0, &vec![0.0f32; n_global]).unwrap();
        assert!(fresh.is_empty());
    }

    #[test]
    fn strong_input_causes_spikes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt
            .load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        let mut w = vec![0.0f32; n_local * n_global];
        // neuron 5 listens to global 100 with a huge weight
        w[5 * n_global + 100] = 500.0;
        let mut shard = ShardSim::new(model, w, 0);
        let mut spikes_in = vec![0.0f32; n_global];
        spikes_in[100] = 1.0;
        let s = shard.step(&spikes_in).unwrap();
        assert_eq!(s, &[5]);
        assert_eq!(shard.total_spikes, 1);
        assert!(shard.mean_rate() > 0.0);
    }
}
