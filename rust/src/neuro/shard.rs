//! One neuron shard: AOT-compiled LIF dynamics + its spike I/O mapping.
//!
//! `ShardSim` owns the packed state of the neurons behind one FPGA, the
//! shard's weight matrix, and a handle to the compiled step executable.
//! The coordinator calls [`ShardSim::step`] once per timestep with the
//! global spike-count vector assembled from the events the simulated
//! Extoll fabric delivered, and receives the local spike indices to feed
//! back into the fabric.

use anyhow::Result;

use crate::runtime::{ShardModel, WeightBuffer};

/// Mapping local neuron index → (HICANN link, pulse address). The 8
/// HICANNs of an FPGA interleave across the shard.
pub fn pulse_of_neuron(local: u32) -> (u8, u16) {
    ((local & 7) as u8, (local >> 3) as u16)
}

/// Inverse of [`pulse_of_neuron`].
pub fn neuron_of_pulse(hicann: u8, pulse: u16) -> u32 {
    ((pulse as u32) << 3) | hicann as u32
}

/// Step-invariant weights: retained by the runtime when the upload
/// succeeds, host-resident fallback otherwise — exactly one copy of the
/// n_local×n_global matrix either way.
enum Weights {
    Uploaded(WeightBuffer),
    Host(Vec<f32>),
}

/// A live shard: state + weights + compiled step.
pub struct ShardSim {
    model: ShardModel,
    /// Packed `[3, n_local]` state.
    state: Vec<f32>,
    weights: Weights,
    /// Global index of this shard's first neuron.
    pub global_base: u32,
    /// Spikes emitted in the most recent step (local indices).
    pub last_spikes: Vec<u32>,
    /// Total spikes so far.
    pub total_spikes: u64,
    pub steps: u64,
}

impl ShardSim {
    pub fn new(model: ShardModel, weights: Vec<f32>, global_base: u32) -> Self {
        let n_local = model.n_local();
        assert_eq!(weights.len(), n_local * model.n_global());
        let weights = match model.upload_weights(&weights) {
            Ok(buf) => Weights::Uploaded(buf),
            Err(_) => Weights::Host(weights),
        };
        ShardSim {
            model,
            state: vec![0.0; 3 * n_local],
            weights,
            global_base,
            last_spikes: Vec::new(),
            total_spikes: 0,
            steps: 0,
        }
    }

    pub fn n_local(&self) -> usize {
        self.model.n_local()
    }

    pub fn n_global(&self) -> usize {
        self.model.n_global()
    }

    /// Randomize initial membrane potentials in `[lo, hi)` to desynchronize
    /// the network (all-zero init makes every neuron fire in lockstep).
    pub fn randomize_v(&mut self, rng: &mut crate::util::rng::Rng, lo: f32, hi: f32) {
        let n = self.n_local();
        for v in &mut self.state[..n] {
            *v = lo + (hi - lo) * rng.f64() as f32;
        }
    }

    /// Advance one timestep given the global spike-count vector; records
    /// and returns the local indices that spiked.
    pub fn step(&mut self, spikes_global: &[f32]) -> Result<&[u32]> {
        let out = match &self.weights {
            Weights::Uploaded(buf) => self.model.step_with(&self.state, spikes_global, buf)?,
            Weights::Host(w) => self.model.step(&self.state, spikes_global, w)?,
        };
        self.state = out;
        let n = self.n_local();
        self.last_spikes.clear();
        let spikes = ShardModel::spikes_of(&self.state, n);
        for (i, &s) in spikes.iter().enumerate() {
            if s > 0.0 {
                self.last_spikes.push(i as u32);
            }
        }
        self.total_spikes += self.last_spikes.len() as u64;
        self.steps += 1;
        Ok(&self.last_spikes)
    }

    /// Mean firing rate in spikes/neuron/step.
    pub fn mean_rate(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.total_spikes as f64 / (self.steps as f64 * self.n_local() as f64)
    }

    /// Membrane potential of neuron `i` (diagnostics).
    pub fn v(&self, i: usize) -> f32 {
        self.state[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir, Runtime};

    #[test]
    fn pulse_mapping_roundtrip() {
        for local in [0u32, 1, 7, 8, 255, 1023, 4095] {
            let (h, p) = pulse_of_neuron(local);
            assert!(h < 8);
            assert!(p < (1 << 12));
            assert_eq!(neuron_of_pulse(h, p), local);
        }
    }

    fn shard_manifest(rt: &Runtime) -> crate::runtime::Manifest {
        rt.load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap()
            .manifest
    }

    #[test]
    fn shard_steps_and_counts_spikes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt
            .load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        // zero weights: dynamics driven only by the baked-in i_ext; over
        // 50 steps the membrane follows v = i_ext·(1 - decay^k), still
        // below threshold → no spikes yet
        let (i_ext, decay) = {
            let m = &shard_manifest(&rt);
            (m.i_ext, m.decay)
        };
        let mut shard = ShardSim::new(model, vec![0.0; n_local * n_global], 0);
        let spikes_in = vec![0.0f32; n_global];
        for _ in 0..50 {
            let s = shard.step(&spikes_in).unwrap();
            assert!(s.is_empty());
        }
        assert_eq!(shard.total_spikes, 0);
        assert_eq!(shard.steps, 50);
        let expect = (i_ext * (1.0 - decay.powi(50))) as f32;
        assert!(
            (shard.v(0) - expect).abs() < 1e-3,
            "v={} expect={expect}",
            shard.v(0)
        );
    }

    #[test]
    fn strong_input_causes_spikes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let model = rt
            .load_shard_model(&artifacts_dir(), "shard_256x1024")
            .unwrap();
        let n_local = model.n_local();
        let n_global = model.n_global();
        let mut w = vec![0.0f32; n_local * n_global];
        // neuron 5 listens to global 100 with a huge weight
        w[5 * n_global + 100] = 500.0;
        let mut shard = ShardSim::new(model, w, 0);
        let mut spikes_in = vec![0.0f32; n_global];
        spikes_in[100] = 1.0;
        let s = shard.step(&spikes_in).unwrap();
        assert_eq!(s, &[5]);
        assert_eq!(shard.total_spikes, 1);
        assert!(shard.mean_rate() > 0.0);
    }
}
