//! Neuron-dynamics layer: bridges the AOT-compiled JAX/Pallas LIF shards
//! (executed through [`crate::runtime`]) and the simulated BrainScaleS
//! communication fabric. Each shard plays the role of the HICANN chips
//! behind one communication FPGA.

pub mod shard;
pub mod weights;

pub use shard::{neuron_of_pulse, pulse_of_neuron, ShardArena, ShardSim};
pub use weights::{build_weights, fill_weights, weights_shape};
