//! Synaptic weight generation from the cortical-microcircuit statistics.
//!
//! Builds the `f32[n_local, n_global]` weight matrix of one shard from the
//! Potjans-Diesmann connection probabilities: pairwise Bernoulli
//! connectivity, excitatory/inhibitory signs by source population, and
//! deterministic seeding so every run (and every shard) reproduces the
//! same network.

use crate::util::rng::Rng;
use crate::workload::microcircuit::{Microcircuit, CONN_PROB};

/// Map a global neuron index to its population under a per-shard layout
/// where each shard hosts `sizes_local[p]` neurons of population `p`,
/// laid out population-by-population, shard-by-shard.
pub fn population_of(local_index: u32, sizes_local: &[u32; 8]) -> usize {
    let mut acc = 0;
    for (p, &s) in sizes_local.iter().enumerate() {
        acc += s;
        if local_index < acc {
            return p;
        }
    }
    panic!("index {local_index} outside shard of {} neurons", acc);
}

/// Build the weight matrix for one shard.
///
/// `slices[f]` gives each shard's per-population sizes (all shards use the
/// same population-major local layout). `shard` is the target shard index;
/// columns cover the global space `sum_f sum_p slices[f][p]` in shard-major
/// order. `w_exc`/`w_inh` are the synaptic efficacies; probabilities come
/// from [`CONN_PROB`], optionally scaled by `k_scale` (down-scaled nets
/// keep realistic input counts by upscaling weights externally).
pub fn build_weights(
    mc: &Microcircuit,
    slices: &[[u32; 8]],
    shard: usize,
    w_exc: f32,
    w_inh: f32,
    k_scale: f64,
    seed: u64,
) -> Vec<f32> {
    let (n_local, n_global) = weights_shape(slices, shard);
    let mut w = vec![0.0f32; n_local * n_global];
    fill_weights(mc, slices, shard, w_exc, w_inh, k_scale, seed, &mut w);
    w
}

/// `(n_local, n_global)` — the shape of shard `shard`'s weight matrix.
pub fn weights_shape(slices: &[[u32; 8]], shard: usize) -> (usize, usize) {
    let n_local: u32 = slices[shard].iter().sum();
    let n_global: u32 = slices.iter().map(|s| s.iter().sum::<u32>()).sum();
    (n_local as usize, n_global as usize)
}

/// Core generator: fill a zeroed `f32[n_local, n_global]` slice in place.
/// Shared by [`build_weights`] (own `Vec`) and the arena path
/// ([`crate::sim::F32Arena::alloc_with`]) — both produce bit-identical
/// matrices because the RNG draw order depends only on `(slices, shard,
/// seed)`, never on where the output lives.
#[allow(clippy::too_many_arguments)]
pub fn fill_weights(
    mc: &Microcircuit,
    slices: &[[u32; 8]],
    shard: usize,
    w_exc: f32,
    w_inh: f32,
    k_scale: f64,
    seed: u64,
    w: &mut [f32],
) {
    let _ = mc;
    let (n_local, n_global) = weights_shape(slices, shard);
    assert_eq!(w.len(), n_local * n_global, "weight buffer shape mismatch");
    let mut rng = Rng::new(seed ^ ((shard as u64) << 32));
    let mut col_base = 0u32;
    for src_slice in slices {
        let src_n: u32 = src_slice.iter().sum();
        for sl in 0..src_n {
            let sp = population_of(sl, src_slice);
            let col = (col_base + sl) as usize;
            for tl in 0..n_local as u32 {
                let tp = population_of(tl, &slices[shard]);
                let p = CONN_PROB[tp][sp] * k_scale;
                if p > 0.0 && rng.chance(p.min(1.0)) {
                    let weight = if sp % 2 == 0 { w_exc } else { w_inh };
                    w[tl as usize * n_global + col] = weight;
                }
            }
        }
        col_base += src_n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::microcircuit::Microcircuit;

    fn slices_2() -> Vec<[u32; 8]> {
        vec![[8, 4, 8, 4, 2, 1, 4, 1]; 2] // 32 neurons per shard, 64 global
    }

    #[test]
    fn population_mapping() {
        let s = [8u32, 4, 8, 4, 2, 1, 4, 1];
        assert_eq!(population_of(0, &s), 0);
        assert_eq!(population_of(7, &s), 0);
        assert_eq!(population_of(8, &s), 1);
        assert_eq!(population_of(31, &s), 7);
    }

    #[test]
    #[should_panic(expected = "outside shard")]
    fn population_out_of_range() {
        let s = [1u32; 8];
        let _ = population_of(8, &s);
    }

    #[test]
    fn weights_deterministic_and_signed() {
        let mc = Microcircuit::new(0.001);
        let slices = slices_2();
        let a = build_weights(&mc, &slices, 0, 0.5, -2.0, 30.0, 42);
        let b = build_weights(&mc, &slices, 0, 0.5, -2.0, 30.0, 42);
        assert_eq!(a, b);
        let c = build_weights(&mc, &slices, 0, 0.5, -2.0, 30.0, 43);
        assert_ne!(a, c, "different seed must differ");
        // signs: columns from even (E) populations are ≥ 0, odd (I) ≤ 0
        let n_global = 64;
        let mut pos = 0;
        let mut neg = 0;
        for (idx, &v) in a.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let col = (idx % n_global) as u32;
            let src_slice = &slices[col as usize / 32];
            let sp = population_of(col % 32, src_slice);
            if sp % 2 == 0 {
                assert!(v > 0.0);
                pos += 1;
            } else {
                assert!(v < 0.0);
                neg += 1;
            }
        }
        assert!(pos > 0 && neg > 0, "need both E and I synapses");
    }

    #[test]
    fn arena_fill_matches_vec_build_exactly() {
        let mc = Microcircuit::new(0.001);
        let slices = slices_2();
        let via_vec = build_weights(&mc, &slices, 1, 0.5, -2.0, 30.0, 42);
        let mut arena = crate::sim::F32Arena::new();
        let (n_local, n_global) = weights_shape(&slices, 1);
        let row = arena.alloc_with(n_local * n_global, |w| {
            fill_weights(&mc, &slices, 1, 0.5, -2.0, 30.0, 42, w);
        });
        assert_eq!(arena.row(row), via_vec.as_slice());
    }

    #[test]
    fn connection_density_tracks_probability() {
        let mc = Microcircuit::new(0.01);
        // single population pair: make a custom slice with only L2/3E
        let slices = vec![[64u32, 0, 0, 0, 0, 0, 0, 0]; 2];
        let w = build_weights(&mc, &slices, 0, 1.0, -1.0, 1.0, 7);
        let nz = w.iter().filter(|&&v| v != 0.0).count();
        // expected density = CONN_PROB[0][0] ≈ 0.1009 over 64×128 entries
        let expect = 0.1009 * (64.0 * 128.0);
        assert!(
            (nz as f64 - expect).abs() < expect * 0.35,
            "nz={nz} expect≈{expect}"
        );
    }
}
