//! Wafer-module assembly (paper §1, Fig. 1): 48 communication FPGAs per
//! wafer gathered at 8 concentrator nodes of the Extoll torus, plus the
//! multi-wafer system builder.

pub mod concentrator;
pub mod system;

pub use concentrator::{Concentrator, ConcentratorConfig, FPGAS_PER_CONCENTRATOR};
pub use system::{
    FaultTotals, System, SystemConfig, Wafer, CONCENTRATORS_PER_WAFER, FPGAS_PER_WAFER,
};
