//! Concentrator node (paper §1, Fig. 1).
//!
//! "6 of these FPGAs are gathered at one of 8 concentrator nodes per wafer
//! module, connecting them to one torus node, respectively."
//!
//! The concentrator is the aggregation switch between 6 communication
//! FPGAs (each with its own Extoll link) and the local port of one
//! Tourmalet: it muxes FPGA packets into the NIC (crediting the FPGA when
//! a packet is taken), and demuxes delivered packets to the right FPGA by
//! the `dst_fpga` field of the spike batch.

use crate::extoll::packet::PacketKind;
use crate::extoll::torus::LOCAL_PORT;
use crate::msg::Msg;
use crate::sim::{Actor, ActorId, Ctx, Time};

/// Number of FPGAs gathered per concentrator (paper Fig. 1).
pub const FPGAS_PER_CONCENTRATOR: usize = 6;

/// Concentrator configuration.
#[derive(Clone, Copy, Debug)]
pub struct ConcentratorConfig {
    /// Mux latency per packet towards the NIC.
    pub mux_latency: Time,
    /// Demux latency per packet towards an FPGA.
    pub demux_latency: Time,
}

impl Default for ConcentratorConfig {
    fn default() -> Self {
        ConcentratorConfig {
            mux_latency: Time::from_ns(25),
            demux_latency: Time::from_ns(25),
        }
    }
}

/// Concentrator statistics.
#[derive(Clone, Debug, Default)]
pub struct ConcentratorStats {
    pub muxed: u64,
    pub demuxed: u64,
    pub host_bound: u64,
}

/// The concentrator actor.
pub struct Concentrator {
    pub cfg: ConcentratorConfig,
    /// Downstream FPGAs (index = `dst_fpga`).
    fpgas: Vec<Option<ActorId>>,
    /// Our Tourmalet NIC.
    nic: Option<ActorId>,
    pub stats: ConcentratorStats,
}

impl Default for Concentrator {
    fn default() -> Self {
        Self::new(ConcentratorConfig::default(), FPGAS_PER_CONCENTRATOR)
    }
}

impl Concentrator {
    pub fn new(cfg: ConcentratorConfig, n_fpgas: usize) -> Self {
        Concentrator {
            cfg,
            fpgas: vec![None; n_fpgas],
            nic: None,
            stats: ConcentratorStats::default(),
        }
    }

    pub fn attach_nic(&mut self, id: ActorId) {
        self.nic = Some(id);
    }

    pub fn attach_fpga(&mut self, idx: u8, id: ActorId) {
        self.fpgas[idx as usize] = Some(id);
    }
}

impl Actor<Msg> for Concentrator {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            // FPGA → fabric: mux into the NIC, credit the FPGA
            Msg::Inject(mut p) => {
                self.stats.muxed += 1;
                if let Some((src_actor, _, _)) = p.ingress.take() {
                    // concentrator input buffer slot freed once forwarded
                    ctx.send(
                        src_actor,
                        self.cfg.mux_latency,
                        Msg::Credit {
                            port: LOCAL_PORT,
                            vc: 0,
                        },
                    );
                }
                let nic = self.nic.expect("concentrator has no nic");
                ctx.send(nic, self.cfg.mux_latency, Msg::Inject(p));
            }
            // fabric → FPGA: demux by dst_fpga
            Msg::Deliver(p) => {
                match &p.kind {
                    PacketKind::SpikeBatch { dst_fpga, .. } => {
                        self.stats.demuxed += 1;
                        let f = self.fpgas[*dst_fpga as usize]
                            .unwrap_or_else(|| panic!("no fpga {dst_fpga} attached"));
                        ctx.send(f, self.cfg.demux_latency, Msg::Deliver(p));
                    }
                    PacketKind::Notification { .. } | PacketKind::RmaPut { .. } => {
                        // host-protocol packets addressed to a wafer node are
                        // routed to FPGA 0's stream unit by convention
                        self.stats.host_bound += 1;
                        let f = self.fpgas[0].expect("no fpga 0 attached");
                        ctx.send(f, self.cfg.demux_latency, Msg::Deliver(p));
                    }
                    PacketKind::Raw => {
                        self.stats.demuxed += 1;
                        // raw packets are used by fabric-level tests only;
                        // deliver to FPGA 0 if attached, else drop
                        if let Some(f) = self.fpgas[0] {
                            ctx.send(f, self.cfg.demux_latency, Msg::Deliver(p));
                        }
                    }
                }
            }
            Msg::Credit { .. } => {}
            other => panic!("concentrator: unexpected message {other:?}"),
        }
    }

    fn name(&self) -> String {
        "concentrator".to_string()
    }

    /// Lives with its Tourmalet: concentrator↔NIC messages are local-port
    /// traffic (mux latency < any torus-link latency), so both must share
    /// a PDES domain.
    fn placement(&self) -> crate::sim::Placement {
        match self.nic {
            Some(nic) => crate::sim::Placement::With(nic),
            None => crate::sim::Placement::Free,
        }
    }

    /// The concentrator is stateless apart from its stats — wiring and
    /// config survive, stats restart from zero.
    fn reset(&mut self) -> bool {
        self.stats = ConcentratorStats::default();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extoll::packet::Packet;
    use crate::extoll::torus::NodeAddr;
    use crate::fpga::event::RoutedEvent;
    use crate::fpga::lookup::EndpointAddr;
    use crate::sim::Sim;

    struct Probe {
        got: Vec<(Time, Msg)>,
    }

    impl Actor<Msg> for Probe {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            self.got.push((ctx.now(), msg));
        }
    }

    #[test]
    fn mux_forwards_and_credits() {
        let mut sim = Sim::new();
        let conc = sim.add(Concentrator::default());
        let nic = sim.add(Probe { got: vec![] });
        let fpga = sim.add(Probe { got: vec![] });
        sim.get_mut::<Concentrator>(conc).attach_nic(nic);
        let mut p = Packet::raw(NodeAddr(0), NodeAddr(1), 64, Time::ZERO, 1);
        p.ingress = Some((fpga, LOCAL_PORT, 0));
        sim.schedule(Time::ZERO, conc, Msg::Inject(p));
        sim.run_to_completion();
        let nic_probe: &Probe = sim.get(nic);
        assert_eq!(nic_probe.got.len(), 1);
        assert!(matches!(nic_probe.got[0].1, Msg::Inject(_)));
        assert_eq!(nic_probe.got[0].0, Time::from_ns(25));
        let fpga_probe: &Probe = sim.get(fpga);
        assert!(matches!(
            fpga_probe.got[0].1,
            Msg::Credit {
                port: LOCAL_PORT,
                vc: 0
            }
        ));
    }

    #[test]
    fn demux_routes_by_dst_fpga() {
        let mut sim = Sim::new();
        let conc = sim.add(Concentrator::default());
        let fpgas: Vec<_> = (0..6).map(|_| sim.add(Probe { got: vec![] })).collect();
        for (i, &f) in fpgas.iter().enumerate() {
            sim.get_mut::<Concentrator>(conc).attach_fpga(i as u8, f);
        }
        for fidx in [0u8, 3, 5] {
            let p = Packet::spike_batch(
                NodeAddr(7),
                EndpointAddr::new(NodeAddr(0), fidx),
                vec![RoutedEvent::new(1, 2, Time::ZERO)],
                Time::ZERO,
                fidx as u64,
            );
            sim.schedule(Time::ZERO, conc, Msg::Deliver(p));
        }
        sim.run_to_completion();
        for (i, &f) in fpgas.iter().enumerate() {
            let probe: &Probe = sim.get(f);
            let expect = matches!(i, 0 | 3 | 5) as usize;
            assert_eq!(probe.got.len(), expect, "fpga {i}");
        }
    }
}
