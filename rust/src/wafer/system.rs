//! Multi-wafer system builder (paper Fig. 1).
//!
//! Assembles the complete simulated machine: an Extoll 3D-torus fabric of
//! Tourmalet NICs, one or more BrainScaleS wafer modules — each with 48
//! communication FPGAs gathered at 8 concentrator nodes (6 FPGAs per
//! concentrator, the topology the paper argues is bandwidth-optimal) —
//! plus optional host nodes. The concentrators-per-wafer fan-in is a
//! parameter so `bench_topology` can sweep the alternatives the paper's
//! Fig. 1 implicitly compares against.
//!
//! Wafers occupy consecutive torus node addresses, which is what lets
//! the contiguous-address PDES domain split
//! (`extoll::torus::DomainMap`) keep whole wafers inside one domain —
//! see `docs/ARCHITECTURE.md` §1 for the layer map and §3 for a spike's
//! path through this assembly.

use std::sync::Arc;

use crate::extoll::network::Fabric;
use crate::extoll::nic::{Nic, NicConfig, NicStats};
use crate::extoll::torus::{NodeAddr, TorusSpec};
use crate::fault::FaultModel;
use crate::fpga::fpga::{Fpga, FpgaConfig};
use crate::fpga::lookup::{EndpointAddr, RxEntry, TxEntry};
use crate::fpga::manager::ManagerConfig;
use crate::msg::Msg;
use crate::sim::{ActorId, Arena, Sim, SimEpoch, Time};
use crate::util::report::Report;
use crate::util::stats::Histogram;

use super::concentrator::{Concentrator, ConcentratorConfig};

/// Number of reticles (= communication FPGAs) per wafer module (paper §1).
pub const FPGAS_PER_WAFER: usize = 48;
/// Concentrator nodes per wafer in the paper's proposed topology (Fig. 1).
pub const CONCENTRATORS_PER_WAFER: usize = 8;

/// System configuration.
#[derive(Clone, Copy, Debug)]
pub struct SystemConfig {
    /// Number of wafer modules.
    pub n_wafers: usize,
    /// Torus dimensions; must provide ≥ `n_wafers × concentrators_per_wafer`
    /// nodes (extra nodes may host compute hosts).
    pub torus: TorusSpec,
    /// NIC/link parameters.
    pub nic: NicConfig,
    /// Bucket-manager parameters for every FPGA.
    pub manager: ManagerConfig,
    /// Concentrator mux/demux latencies.
    pub concentrator: ConcentratorConfig,
    /// FPGAs per wafer (48 in hardware; smaller for unit experiments).
    pub fpgas_per_wafer: usize,
    /// Concentrator nodes per wafer — the Fig. 1 sweep parameter.
    pub concentrators_per_wafer: usize,
    /// FPGA egress link rate (Gbit/s).
    pub fpga_egress_gbps: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(4, 2, 2),
            nic: NicConfig::default(),
            manager: ManagerConfig::default(),
            concentrator: ConcentratorConfig::default(),
            fpgas_per_wafer: FPGAS_PER_WAFER,
            concentrators_per_wafer: CONCENTRATORS_PER_WAFER,
            fpga_egress_gbps: 4.0 * 8.4,
        }
    }
}

/// One built wafer module.
#[derive(Clone, Debug)]
pub struct Wafer {
    pub index: usize,
    /// Torus nodes of this wafer's concentrators.
    pub nodes: Vec<NodeAddr>,
    pub concentrators: Vec<ActorId>,
    /// FPGA actors, indexed `concentrator * fan_in + slot`.
    pub fpgas: Vec<ActorId>,
    /// Network endpoint of each FPGA (parallel to `fpgas`).
    pub endpoints: Vec<EndpointAddr>,
}

/// The assembled system.
pub struct System {
    pub cfg: SystemConfig,
    pub fabric: Fabric,
    pub wafers: Vec<Wafer>,
    /// Simulator snapshot taken at the end of the build: actor count,
    /// queue kind and capacity. [`crate::sim::Sim::reset_to_epoch`] rewinds
    /// a finished run back to exactly this state, dropping post-build
    /// actors (generators) and restoring every fabric actor — which is
    /// what lets one build serve many executes (`reuse=fabric`).
    pub epoch: SimEpoch,
    /// The fault model installed on the NICs, if any — retained so
    /// post-run collectors can report the sampled fault set (failed
    /// cables etc.) without rebuilding it.
    pub fault: Option<Arc<FaultModel>>,
}

/// Hot per-FPGA counters, one row per FPGA in [`System::fpgas`] order.
/// [`System::snapshot_counters`] gathers them in a single pass over the
/// boxed actor heap into a contiguous [`Arena`]; report collectors then
/// sum dense rows instead of chasing actor pointers once per metric —
/// at rack scale (~10³ FPGAs) that turns seven heap walks into one.
#[derive(Clone, Copy, Debug, Default)]
pub struct FpgaCounters {
    pub events_in: u64,
    pub events_out: u64,
    pub packets_out: u64,
    pub rx_events: u64,
    pub deadline_misses: u64,
    pub dropped: u64,
    pub unrouted: u64,
    pub flush_deadline: u64,
    pub flush_full: u64,
    pub flush_external: u64,
    pub flush_evict: u64,
    pub evictions: u64,
}

/// System-wide sums of the per-FPGA bucket-manager / drop counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ManagerTotals {
    pub dropped: u64,
    pub unrouted: u64,
    pub flush_deadline: u64,
    pub flush_full: u64,
    pub flush_external: u64,
    pub flush_evict: u64,
    pub evictions: u64,
}

/// System-wide sums of the per-NIC fault counters, plus merged hop
/// histograms for detour-inflation reporting.
#[derive(Clone, Debug, Default)]
pub struct FaultTotals {
    pub injected_packets: u64,
    pub injected_events: u64,
    pub delivered_events: u64,
    pub lost_packets: u64,
    pub lost_events: u64,
    pub undeliverable_packets: u64,
    pub undeliverable_events: u64,
    pub detour_hops: u64,
    /// Torus hops actually taken by delivered packets.
    pub hops: Histogram,
    /// Fault-free shortest-path distances of the same packets.
    pub min_hops: Histogram,
    /// Link-layer retransmission copies transmitted (`reliability=link`).
    pub retransmissions: u64,
    /// NACKs sent (CRC failures + sequence gaps).
    pub nacks: u64,
    /// Retransmission-timer expirations that triggered a replay.
    pub timeouts: u64,
    /// Packets the link layer recovered (ACKed after ≥1 retransmission).
    pub recovered_packets: u64,
    /// Spike events inside recovered packets.
    pub recovered_events: u64,
    /// Received packets dropped as already-accepted duplicates.
    pub duplicate_packets: u64,
    /// Packets abandoned after the retry budget — the loss the link layer
    /// could NOT recover (also counted in `undeliverable_packets`).
    pub residual_loss_packets: u64,
    /// Spike events inside abandoned packets.
    pub residual_loss_events: u64,
    /// Recovery latency (first transmission → cumulative ACK), ps.
    pub recovery_ps: Histogram,
}

impl FaultTotals {
    /// Delivered / injected spike events — 1.0 on a healthy fabric (and
    /// when nothing was injected), strictly below under loss or failures.
    pub fn deliverability(&self) -> f64 {
        if self.injected_events == 0 {
            1.0
        } else {
            self.delivered_events as f64 / self.injected_events as f64
        }
    }

    /// Mean(hops) / mean(min-hops) over delivered packets — exactly 1.0
    /// fault-free (dimension-order routes are minimal), above it when
    /// detours inflate paths. 1.0 when nothing (or only self-traffic,
    /// min-hop sum 0) was delivered.
    pub fn hop_inflation(&self) -> f64 {
        if self.min_hops.sum() == 0 {
            1.0
        } else {
            self.hops.sum() as f64 / self.min_hops.sum() as f64
        }
    }
}

impl System {
    /// Build fabric, wafers, concentrators and FPGAs, and wire everything.
    pub fn build(sim: &mut Sim<Msg>, cfg: SystemConfig) -> System {
        System::build_with(sim, cfg, None)
    }

    /// [`System::build`] with an optional fault model installed on every
    /// NIC (the `None` path is byte-identical to a fault-free build).
    pub fn build_with(
        sim: &mut Sim<Msg>,
        cfg: SystemConfig,
        fault: Option<&Arc<FaultModel>>,
    ) -> System {
        assert!(
            cfg.fpgas_per_wafer % cfg.concentrators_per_wafer == 0,
            "fpgas_per_wafer must divide evenly among concentrators"
        );
        let fan_in = cfg.fpgas_per_wafer / cfg.concentrators_per_wafer;
        assert!(fan_in <= 64, "endpoint addressing supports ≤64 FPGAs per node");
        let needed = cfg.n_wafers * cfg.concentrators_per_wafer;
        assert!(
            cfg.torus.n_nodes() >= needed,
            "torus has {} nodes, need {needed}",
            cfg.torus.n_nodes()
        );
        let fabric = Fabric::build_with(sim, cfg.torus, cfg.nic, fault);
        let mut wafers = Vec::with_capacity(cfg.n_wafers);
        for w in 0..cfg.n_wafers {
            let mut nodes = Vec::new();
            let mut concentrators = Vec::new();
            let mut fpgas = Vec::new();
            let mut endpoints = Vec::new();
            for c in 0..cfg.concentrators_per_wafer {
                let node = NodeAddr((w * cfg.concentrators_per_wafer + c) as u16);
                let conc = sim.add(Concentrator::new(cfg.concentrator, fan_in));
                sim.get_mut::<Nic>(fabric.nics[node.0 as usize]).attach_local(conc);
                sim.get_mut::<Concentrator>(conc)
                    .attach_nic(fabric.nics[node.0 as usize]);
                for slot in 0..fan_in {
                    let endpoint = EndpointAddr::new(node, slot as u8);
                    let fpga = sim.add(Fpga::new(FpgaConfig {
                        endpoint,
                        manager: cfg.manager,
                        egress_gbps: cfg.fpga_egress_gbps,
                        ..FpgaConfig::default()
                    }));
                    sim.get_mut::<Fpga>(fpga).attach_uplink(conc);
                    sim.get_mut::<Concentrator>(conc).attach_fpga(slot as u8, fpga);
                    fpgas.push(fpga);
                    endpoints.push(endpoint);
                }
                nodes.push(node);
                concentrators.push(conc);
            }
            wafers.push(Wafer {
                index: w,
                nodes,
                concentrators,
                fpgas,
                endpoints,
            });
        }
        System {
            cfg,
            fabric,
            wafers,
            epoch: sim.mark_epoch(),
            fault: fault.cloned(),
        }
    }

    /// Total FPGAs in the system.
    pub fn n_fpgas(&self) -> usize {
        self.wafers.iter().map(|w| w.fpgas.len()).sum()
    }

    /// Iterate (wafer index, fpga slot, actor id, endpoint).
    pub fn fpgas(&self) -> impl Iterator<Item = (usize, usize, ActorId, EndpointAddr)> + '_ {
        self.wafers.iter().flat_map(|w| {
            w.fpgas
                .iter()
                .zip(w.endpoints.iter())
                .enumerate()
                .map(move |(i, (&id, &ep))| (w.index, i, id, ep))
        })
    }

    /// Program a spike route: events with `pulse_addr` on `hicann` of the
    /// source FPGA are sent to the destination FPGA under `guid`, where
    /// they are multicast to `hicann_mask` with translated `local_pulse`.
    #[allow(clippy::too_many_arguments)]
    pub fn program_route(
        &self,
        sim: &mut Sim<Msg>,
        src: (usize, usize),
        hicann: u8,
        pulse_addr: u16,
        dst: (usize, usize),
        guid: u16,
        hicann_mask: u8,
        local_pulse: u16,
    ) {
        let dst_ep = self.wafers[dst.0].endpoints[dst.1];
        let src_actor = self.wafers[src.0].fpgas[src.1];
        sim.get_mut::<Fpga>(src_actor).tx_lut.set(
            hicann,
            pulse_addr,
            TxEntry {
                dest: dst_ep,
                guid,
            },
        );
        let dst_actor = self.wafers[dst.0].fpgas[dst.1];
        sim.get_mut::<Fpga>(dst_actor).rx_lut.set(
            guid,
            RxEntry {
                hicann_mask,
                pulse_addr: local_pulse,
            },
        );
    }

    // ---- aggregated statistics -------------------------------------------

    /// Snapshot every FPGA's hot counters into one contiguous SoA arena
    /// (one pass over the actor heap, rows in [`System::fpgas`] order).
    /// Sums over the rows are byte-identical to the per-metric collectors
    /// below — same values, same iteration order.
    pub fn snapshot_counters(&self, sim: &Sim<Msg>) -> Arena<FpgaCounters> {
        let mut arena = Arena::with_capacity(self.n_fpgas());
        for (_, _, id, _) in self.fpgas() {
            let f: &Fpga = sim.get(id);
            arena.push(FpgaCounters {
                events_in: f.stats.events_in,
                events_out: f.stats.events_out,
                packets_out: f.stats.packets_out,
                rx_events: f.stats.rx_events,
                deadline_misses: f.stats.playback.deadline_misses,
                dropped: f.stats.dropped_events,
                unrouted: f.stats.tx_unrouted,
                flush_deadline: f.mgr.stats.flush_deadline,
                flush_full: f.mgr.stats.flush_full,
                flush_external: f.mgr.stats.flush_external,
                flush_evict: f.mgr.stats.flush_eviction,
                evictions: f.mgr.stats.evictions,
            });
        }
        arena
    }

    pub fn total_events_in(&self, sim: &Sim<Msg>) -> u64 {
        self.fpgas()
            .map(|(_, _, id, _)| sim.get::<Fpga>(id).stats.events_in)
            .sum()
    }

    pub fn total_events_out(&self, sim: &Sim<Msg>) -> u64 {
        self.fpgas()
            .map(|(_, _, id, _)| sim.get::<Fpga>(id).stats.events_out)
            .sum()
    }

    pub fn total_packets_out(&self, sim: &Sim<Msg>) -> u64 {
        self.fpgas()
            .map(|(_, _, id, _)| sim.get::<Fpga>(id).stats.packets_out)
            .sum()
    }

    pub fn total_rx_events(&self, sim: &Sim<Msg>) -> u64 {
        self.fpgas()
            .map(|(_, _, id, _)| sim.get::<Fpga>(id).stats.rx_events)
            .sum()
    }

    pub fn total_deadline_misses(&self, sim: &Sim<Msg>) -> u64 {
        self.fpgas()
            .map(|(_, _, id, _)| sim.get::<Fpga>(id).stats.playback.deadline_misses)
            .sum()
    }

    /// Mean events per packet over the whole system.
    pub fn mean_batch_size(&self, sim: &Sim<Msg>) -> f64 {
        let ev = self.total_events_out(sim);
        let pk = self.total_packets_out(sim);
        if pk == 0 {
            f64::NAN
        } else {
            ev as f64 / pk as f64
        }
    }

    /// Merged end-to-end event latency histogram (source-FPGA ingress →
    /// destination playback), picoseconds.
    pub fn latency_histogram(&self, sim: &Sim<Msg>) -> Histogram {
        let mut h = Histogram::new();
        for (_, _, id, _) in self.fpgas() {
            h.merge(&sim.get::<Fpga>(id).stats.playback.latency_ps);
        }
        h
    }

    /// Sum the per-FPGA bucket-manager and drop counters over the system.
    pub fn manager_totals(&self, sim: &Sim<Msg>) -> ManagerTotals {
        let mut t = ManagerTotals::default();
        for (_, _, id, _) in self.fpgas() {
            let f: &Fpga = sim.get(id);
            t.dropped += f.stats.dropped_events;
            t.unrouted += f.stats.tx_unrouted;
            t.flush_deadline += f.mgr.stats.flush_deadline;
            t.flush_full += f.mgr.stats.flush_full;
            t.flush_external += f.mgr.stats.flush_external;
            t.flush_evict += f.mgr.stats.flush_eviction;
            t.evictions += f.mgr.stats.evictions;
        }
        t
    }

    /// Sum the per-NIC fault counters and merge the hop histograms over
    /// the system — the inputs to the `fault_sweep` deliverability and
    /// hop-inflation metrics.
    pub fn fault_totals(&self, sim: &Sim<Msg>) -> FaultTotals {
        let mut t = FaultTotals::default();
        for &id in &self.fabric.nics {
            let st: &NicStats = &sim.get::<Nic>(id).stats;
            t.injected_packets += st.injected;
            t.injected_events += st.injected_events;
            t.delivered_events += st.delivered_events;
            t.lost_packets += st.lost_packets;
            t.lost_events += st.lost_events;
            t.undeliverable_packets += st.undeliverable_packets;
            t.undeliverable_events += st.undeliverable_events;
            t.detour_hops += st.detour_hops;
            t.hops.merge(&st.hops);
            t.min_hops.merge(&st.min_hops);
            t.retransmissions += st.retransmissions;
            t.nacks += st.nacks;
            t.timeouts += st.timeouts;
            t.recovered_packets += st.recovered_packets;
            t.recovered_events += st.recovered_events;
            t.duplicate_packets += st.duplicate_packets;
            t.residual_loss_packets += st.residual_loss_packets;
            t.residual_loss_events += st.residual_loss_events;
            t.recovery_ps.merge(&st.recovery_ps);
        }
        t
    }

    /// Collect the standard communication-path metrics of a finished run
    /// into a [`Report`] — the paper's headline numbers (aggregation
    /// efficiency, end-to-end latency, deadline misses, link utilization,
    /// flush-reason breakdown). Scenario drivers start from this and
    /// append their scenario-specific metrics.
    pub fn fabric_report(&self, sim: &Sim<Msg>, scenario: &str, duration: Time) -> Report {
        let mut r = Report::new(scenario);
        self.fill_fabric_report(sim, &mut r, duration);
        r
    }

    /// Push the standard fabric metrics into an existing report — the
    /// schema-validated path: scenario drivers pass a
    /// [`Report::with_schema`] report so every push is checked against
    /// the scenario's declared metrics (the fabric declarations live in
    /// `coordinator/traffic.rs` and mirror this push order).
    pub fn fill_fabric_report(&self, sim: &Sim<Msg>, r: &mut Report, duration: Time) {
        // one pass over the boxed actors, then dense sweeps per metric —
        // the sums are byte-identical to the legacy per-metric collectors
        // (same counters, same System::fpgas iteration order)
        let counters = self.snapshot_counters(sim);
        let sum = |field: fn(&FpgaCounters) -> u64| -> u64 {
            counters.rows().iter().map(field).sum()
        };
        let latency = self.latency_histogram(sim);
        let events_out = sum(|c| c.events_out);
        let packets_out = sum(|c| c.packets_out);
        let rx_events = sum(|c| c.rx_events);
        let mean_batch = if packets_out == 0 {
            f64::NAN
        } else {
            events_out as f64 / packets_out as f64
        };
        r.push_unit("duration", duration.secs_f64(), "s");
        r.push_unit("events_in", sum(|c| c.events_in), "events");
        r.push_unit("events_out", events_out, "events");
        r.push_unit("packets_out", packets_out, "packets");
        r.push_unit("rx_events", rx_events, "events");
        r.push_unit("dropped", sum(|c| c.dropped), "events");
        r.push_unit("unrouted", sum(|c| c.unrouted), "events");
        r.push_unit("mean_batch", mean_batch, "events/packet");
        r.push_unit("flush_deadline", sum(|c| c.flush_deadline), "flushes");
        r.push_unit("flush_full", sum(|c| c.flush_full), "flushes");
        r.push_unit("flush_evict", sum(|c| c.flush_evict), "flushes");
        r.push_unit("flush_external", sum(|c| c.flush_external), "flushes");
        r.push_unit("evictions", sum(|c| c.evictions), "evictions");
        r.push_unit("deadline_misses", sum(|c| c.deadline_misses), "events");
        r.push_unit("latency_p50", latency.p50() as f64 / 1e3, "ns");
        r.push_unit("latency_p99", latency.p99() as f64 / 1e3, "ns");
        r.push_unit(
            "max_link_util",
            self.fabric.max_link_utilization(sim, duration),
            "1",
        );
        r.push_unit(
            "delivered_events_per_s",
            rx_events as f64 / duration.secs_f64(),
            "events/s",
        );
    }

    /// Actors receiving the external flush barrier, in schedule order.
    /// Shared by [`System::flush_all`] and the partitioned run loop in
    /// `coordinator/traffic.rs`: both must issue the same schedules in
    /// the same order so they mint identical merge keys (the engine's
    /// determinism contract, `docs/ARCHITECTURE.md` §2.3).
    pub fn flush_targets(&self) -> impl Iterator<Item = ActorId> + '_ {
        self.fpgas().map(|(_, _, id, _)| id)
    }

    /// Flush every FPGA's buckets (experiment barrier) by scheduling the
    /// external-flush timer at the current simulation time.
    pub fn flush_all(&self, sim: &mut Sim<Msg>) {
        let now = sim.now;
        for id in self.flush_targets().collect::<Vec<_>>() {
            sim.schedule(now, id, Msg::Timer(crate::fpga::fpga::TIMER_FLUSH_ALL));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::event::SpikeEvent;
    use crate::sim::Time;

    fn small_cfg() -> SystemConfig {
        SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(4, 2, 2),
            fpgas_per_wafer: 12,
            concentrators_per_wafer: 4,
            ..SystemConfig::default()
        }
    }

    #[test]
    fn build_wires_everything() {
        let mut sim = Sim::new();
        let sys = System::build(&mut sim, small_cfg());
        assert_eq!(sys.n_fpgas(), 24);
        assert_eq!(sys.wafers.len(), 2);
        assert_eq!(sys.wafers[0].concentrators.len(), 4);
        assert_eq!(sys.wafers[1].nodes[0], NodeAddr(4));
        // endpoints are unique
        let mut eps: Vec<u16> = sys.fpgas().map(|(_, _, _, ep)| ep.as_u16()).collect();
        eps.sort_unstable();
        eps.dedup();
        assert_eq!(eps.len(), 24);
    }

    #[test]
    fn cross_wafer_spike_roundtrip() {
        let mut sim = Sim::new();
        let sys = System::build(&mut sim, small_cfg());
        // wafer 0, fpga 0, hicann 2, pulse 77 → wafer 1, fpga 5, guid 900
        sys.program_route(&mut sim, (0, 0), 2, 77, (1, 5), 900, 0b0000_1000, 0x155);
        let src = sys.wafers[0].fpgas[0];
        // deadline 2000 cycles ≈ 9.5 µs
        sim.schedule(
            Time::from_ns(100),
            src,
            Msg::HicannEvent(SpikeEvent::new(2, 77, 2000)),
        );
        sim.run_until(Time::from_ms(1));
        let dst: &Fpga = sim.get(sys.wafers[1].fpgas[5]);
        assert_eq!(dst.stats.rx_events, 1, "event did not arrive");
        assert_eq!(dst.stats.playback.per_hicann[3], 1);
        assert_eq!(dst.rx_buffer.len(), 1);
        assert_eq!(dst.rx_buffer[0].1, 0x155);
        assert_eq!(dst.stats.playback.deadline_misses, 0);
        assert_eq!(sys.total_events_in(&sim), 1);
        assert_eq!(sys.total_events_out(&sim), 1);
    }

    #[test]
    fn paper_topology_dimensions() {
        // the real Fig. 1 numbers: 48 FPGAs, 8 concentrators, 6 per node
        let mut sim = Sim::new();
        let cfg = SystemConfig {
            n_wafers: 1,
            torus: TorusSpec::new(2, 2, 2),
            ..SystemConfig::default()
        };
        let sys = System::build(&mut sim, cfg);
        assert_eq!(sys.n_fpgas(), 48);
        assert_eq!(sys.wafers[0].concentrators.len(), 8);
        assert_eq!(sys.wafers[0].fpgas.len() / sys.wafers[0].concentrators.len(), 6);
    }

    #[test]
    fn flush_all_drains_buckets() {
        let mut sim = Sim::new();
        let sys = System::build(&mut sim, small_cfg());
        sys.program_route(&mut sim, (0, 1), 0, 5, (1, 2), 321, 0b1, 0);
        let src = sys.wafers[0].fpgas[1];
        // far-future deadline: would sit in the bucket for a long time
        sim.schedule(
            Time::from_ns(10),
            src,
            Msg::HicannEvent(SpikeEvent::new(0, 5, 0x3F00)),
        );
        sim.run_until(Time::from_us(10));
        assert_eq!(sys.total_rx_events(&sim), 0, "should still be bucketed");
        sys.flush_all(&mut sim);
        sim.run_until(Time::from_us(100));
        assert_eq!(sys.total_rx_events(&sim), 1, "flush_all did not deliver");
    }

    #[test]
    fn fabric_report_collects_standard_metrics() {
        let mut sim = Sim::new();
        let sys = System::build(&mut sim, small_cfg());
        sys.program_route(&mut sim, (0, 0), 2, 77, (1, 5), 900, 0b0000_1000, 0x155);
        let src = sys.wafers[0].fpgas[0];
        sim.schedule(
            Time::from_ns(100),
            src,
            Msg::HicannEvent(SpikeEvent::new(2, 77, 2000)),
        );
        sim.run_until(Time::from_ms(1));
        let r = sys.fabric_report(&sim, "unit", Time::from_ms(1));
        assert_eq!(r.scenario(), "unit");
        assert_eq!(r.get_count("events_in"), Some(1));
        assert_eq!(r.get_count("rx_events"), Some(1));
        assert_eq!(r.get_count("dropped"), Some(0));
        assert_eq!(r.get_count("unrouted"), Some(0));
        assert!(r.get_f64("latency_p50").unwrap() > 0.0);
        assert!(r.get_f64("delivered_events_per_s").unwrap() > 0.0);
        let totals = sys.manager_totals(&sim);
        assert_eq!(totals.dropped, 0);
        assert!(totals.flush_deadline + totals.flush_full + totals.flush_evict >= 1);
    }

    #[test]
    fn fault_totals_aggregate_and_default_to_perfect_health() {
        let mut sim = Sim::new();
        let sys = System::build(&mut sim, small_cfg());
        let t = sys.fault_totals(&sim);
        assert_eq!(t.deliverability(), 1.0, "empty run counts as healthy");
        assert_eq!(t.hop_inflation(), 1.0);
        // drive one spike through and re-aggregate
        sys.program_route(&mut sim, (0, 0), 2, 77, (1, 5), 900, 0b0000_1000, 0x155);
        let src = sys.wafers[0].fpgas[0];
        sim.schedule(
            Time::from_ns(100),
            src,
            Msg::HicannEvent(SpikeEvent::new(2, 77, 2000)),
        );
        sim.run_until(Time::from_ms(1));
        let t = sys.fault_totals(&sim);
        assert_eq!(t.injected_events, 1);
        assert_eq!(t.delivered_events, 1);
        assert_eq!(t.deliverability(), 1.0);
        assert_eq!(t.hop_inflation(), 1.0, "dimension-order routes are minimal");
        assert_eq!(t.lost_packets, 0);
        assert_eq!(t.undeliverable_packets, 0);
        assert_eq!(t.detour_hops, 0);
    }

    #[test]
    fn build_is_thin_wrapper_over_build_with_none() {
        // regression pin: `System::build` must stay exactly
        // `build_with(sim, cfg, None)` — the fault-free path may never
        // fork (same wiring, same actor ids, same epoch, same physics)
        let mut sim_a = Sim::new();
        let sys_a = System::build(&mut sim_a, small_cfg());
        let mut sim_b = Sim::new();
        let sys_b = System::build_with(&mut sim_b, small_cfg(), None);
        assert_eq!(sys_a.epoch.n_actors, sys_b.epoch.n_actors);
        assert!(sys_a.fault.is_none() && sys_b.fault.is_none());
        for (wa, wb) in sys_a.wafers.iter().zip(&sys_b.wafers) {
            assert_eq!(wa.concentrators, wb.concentrators);
            assert_eq!(wa.fpgas, wb.fpgas);
            assert_eq!(wa.endpoints, wb.endpoints);
            assert_eq!(wa.nodes, wb.nodes);
        }
        // identical trajectories for the same stimulus
        let mut drive = |sim: &mut Sim<Msg>, sys: &System| {
            sys.program_route(sim, (0, 0), 2, 77, (1, 5), 900, 0b0000_1000, 0x155);
            sim.schedule(
                Time::from_ns(100),
                sys.wafers[0].fpgas[0],
                Msg::HicannEvent(SpikeEvent::new(2, 77, 2000)),
            );
            sim.run_until(Time::from_ms(1));
            sys.fabric_report(sim, "pin", Time::from_ms(1)).to_json().to_string()
        };
        assert_eq!(drive(&mut sim_a, &sys_a), drive(&mut sim_b, &sys_b));
    }

    #[test]
    fn counter_snapshot_matches_legacy_collectors() {
        let mut sim = Sim::new();
        let sys = System::build(&mut sim, small_cfg());
        sys.program_route(&mut sim, (0, 0), 2, 77, (1, 5), 900, 0b0000_1000, 0x155);
        sim.schedule(
            Time::from_ns(100),
            sys.wafers[0].fpgas[0],
            Msg::HicannEvent(SpikeEvent::new(2, 77, 2000)),
        );
        sim.run_until(Time::from_ms(1));
        let snap = sys.snapshot_counters(&sim);
        assert_eq!(snap.len(), sys.n_fpgas());
        let sum = |f: fn(&FpgaCounters) -> u64| snap.rows().iter().map(f).sum::<u64>();
        assert_eq!(sum(|c| c.events_in), sys.total_events_in(&sim));
        assert_eq!(sum(|c| c.events_out), sys.total_events_out(&sim));
        assert_eq!(sum(|c| c.packets_out), sys.total_packets_out(&sim));
        assert_eq!(sum(|c| c.rx_events), sys.total_rx_events(&sim));
        assert_eq!(sum(|c| c.deadline_misses), sys.total_deadline_misses(&sim));
        let totals = sys.manager_totals(&sim);
        assert_eq!(sum(|c| c.dropped), totals.dropped);
        assert_eq!(sum(|c| c.unrouted), totals.unrouted);
        assert_eq!(sum(|c| c.flush_deadline), totals.flush_deadline);
        assert_eq!(sum(|c| c.flush_full), totals.flush_full);
        assert_eq!(sum(|c| c.flush_external), totals.flush_external);
        assert_eq!(sum(|c| c.flush_evict), totals.flush_evict);
        assert_eq!(sum(|c| c.evictions), totals.evictions);
        assert!(snap.resident_bytes() >= snap.len() * std::mem::size_of::<FpgaCounters>());
    }

    #[test]
    #[should_panic(expected = "torus has")]
    fn too_small_torus_rejected() {
        let mut sim = Sim::new();
        let cfg = SystemConfig {
            n_wafers: 4,
            torus: TorusSpec::new(2, 2, 2),
            ..SystemConfig::default()
        };
        let _ = System::build(&mut sim, cfg);
    }
}
