//! `bss-extoll` — leader entrypoint for the BrainScaleS-Extoll
//! reproduction. Experiments dispatch generically through the `Scenario`
//! registry (`run <scenario>`), and the sweep runner explores parameter
//! grids (`sweep`) emitting JSON/CSV artifacts.

use anyhow::Result;

use bss_extoll::coordinator::scenario;
use bss_extoll::coordinator::sweep::{apply_override, SweepRunner};
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::serve::client::{run_loadgen, LoadgenConfig};
use bss_extoll::serve::{ServeConfig, Server};
use bss_extoll::util::args::ArgSpec;
use bss_extoll::util::bench::Table;

const USAGE: &str = "\
bss-extoll — BrainScaleS large-scale spike communication over Extoll

USAGE:
  bss-extoll <command> [options]   (--help per command)

COMMANDS:
  run <scenario>  run a registered experiment scenario
  run --list      list registered scenarios
  sweep           run one scenario over a parameter grid (JSON/CSV out)
  serve           experiment job server (TCP JSON-lines, shared cache)
  loadgen         drive a serve instance with concurrent submissions
  info            runtime platform + artifact status

DEPRECATED ALIASES (kept for one release):
  traffic         = run traffic       (+ --rate / --duration-ms)
  microcircuit    = run microcircuit  (+ --steps / --artifact)
  analyze         = run analyze       (+ --wafers / --torus / --concentrators / --scale)

Configs are JSON files (--config); individual knobs override with
--set \"key=v;key=v\" — the same keys sweep axes use, e.g.
  bss-extoll run traffic --set \"rate_hz=2e7;fan_out=2\"
  bss-extoll run traffic --set \"domains=4\"        # partitioned PDES
  bss-extoll run traffic --set \"domains=4;sync=window\"  # windowed reference
  bss-extoll run fault_sweep --set \"fault=fail:0.1|loss:0.01\"  # degraded fabric
  bss-extoll run fault_sweep --set \"fault=@configs/fault_lossy.json\"  # calibrated preset
  bss-extoll run reliability_sweep --set \"fault=loss:0.02;reliability=link\"  # retransmission
  bss-extoll sweep --scenario traffic --grid \"rate_hz=1e6,1e7;n_wafers=2,4\" --csv sweep.csv
  bss-extoll sweep --scenario traffic --grid \"eviction=most_urgent,fullest\" --jobs 4
  bss-extoll sweep --scenario fault_sweep --grid \"fault=none,fail:0.05,fail:0.1\" --csv faults.csv
  bss-extoll sweep --scenario reliability_sweep --set \"fault=loss:0.02\" \\
      --grid \"reliability=off,link\" --csv reliability.csv

Sweep grid points are independent simulations: --jobs N runs them on N
worker threads with results (and artifacts) ordered exactly as --jobs 1.
Within one fabric scenario, --set domains=N partitions the torus into N
conservatively synchronized PDES domains (byte-identical reports);
--set sync=window|channel picks the protocol (per-neighbor channel
clocks by default, the lock-step global-minimum window as reference).
--set fault=<spec> injects deterministic, seed-derived fabric faults
(cable failures, bandwidth degradation, packet loss, latency jitter);
the compact '|'-separated spec form is comma-free so it works as a
sweep axis, and fault=@path loads a calibrated preset file
(configs/fault_lossy.json, configs/fault_degraded.json).
--set reliability=link enables per-link ACK/NACK retransmission with
timeout + backoff (knobs: retx_window, retx_timeout_ns,
retx_max_retries, retx_backoff_cap), recovering CRC-dropped packets
so deliverability returns to 1.0 below the retry limit.
Histogram metrics (latency_dist, reliability_sweep) render as percentile
summaries in CSV with full buckets in the JSON artifact.
Every knob is documented with tuning guidance in docs/TUNING.md.

Service mode (docs/ARCHITECTURE.md §7): `serve` keeps one shared,
byte-budgeted resource cache across all client submissions and streams
queued/preparing/running/done status lines back per job, e.g.
  bss-extoll serve --addr 127.0.0.1:7411 --workers 4 --cache-bytes 64000000
  bss-extoll loadgen --addr 127.0.0.1:7411 --submissions 200 --verify
  bss-extoll serve --smoke 40        # self-contained smoke round (CI)
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "sweep" => cmd_sweep(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "traffic" => cmd_traffic(rest),
        "microcircuit" => cmd_microcircuit(rest),
        "analyze" => cmd_analyze(rest),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command '{other}'\n{USAGE}");
        }
    }
}

/// Load `--config`, falling back to the scenario's own default config
/// (scenarios with machine-shape requirements size themselves).
fn load_config(
    parsed: &bss_extoll::util::args::Parsed,
    scenario: &dyn scenario::Scenario,
) -> Result<ExperimentConfig> {
    match parsed.get("config") {
        "" => Ok(scenario.default_config()),
        path => ExperimentConfig::from_file(path),
    }
}

/// Apply a `--set "key=v;key=v"` override list onto a config (the
/// shared parser also used by service-mode submissions).
fn apply_set(cfg: &mut ExperimentConfig, spec: &str) -> Result<()> {
    cfg.apply_set(spec)
}

fn list_scenarios() {
    let mut t = Table::new("registered scenarios", &["scenario", "about", "metrics"]);
    for s in scenario::registry() {
        t.row(vec![
            s.name().to_string(),
            s.about().to_string(),
            s.metrics().len().to_string(),
        ]);
    }
    t.print();
    // the declared metric schema of every scenario (validated on push,
    // and the sweep CSV's column order)
    for s in scenario::registry() {
        let mut mt = Table::new(
            &format!("{} metrics", s.name()),
            &["metric", "kind", "unit"],
        );
        for d in s.metrics() {
            mt.row(vec![
                d.name.to_string(),
                d.kind.as_str().to_string(),
                d.unit.to_string(),
            ]);
        }
        mt.print();
    }
}

fn find_scenario(name: &str) -> Result<&'static dyn scenario::Scenario> {
    scenario::find(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario '{name}' (registered: {})",
            scenario::names().join(", ")
        )
    })
}

fn cmd_run(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "--list") {
        list_scenarios();
        return Ok(());
    }
    let spec = ArgSpec::new("run", "run a registered experiment scenario")
        .pos("scenario", "scenario name (see `bss-extoll run --list`)")
        .opt("config", "", "experiment config JSON (defaults when empty)")
        .opt("set", "", "config overrides \"key=v;key=v\"")
        .flag("json", "emit the full report as JSON");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let name = p.positional("scenario").expect("required positional");
    let s = find_scenario(name)?;
    let mut cfg = load_config(&p, s)?;
    apply_set(&mut cfg, p.get("set"))?;
    let report = s.run(&cfg)?;
    if p.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        report.print();
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("sweep", "run one scenario over a parameter grid")
        .opt("scenario", "traffic", "scenario to sweep")
        .opt(
            "grid",
            "",
            "sweep axes \"key=v1,v2;key2=v1,v2\" (required; keys as in --set)",
        )
        .opt("config", "", "base experiment config JSON")
        .opt("set", "", "base-config overrides \"key=v;key=v\"")
        .opt("jobs", "1", "worker threads; grid points run in parallel")
        .opt("out", "", "write the aggregate JSON artifact to this file")
        .opt("csv", "", "write the CSV artifact to this file")
        .flag("json", "print the aggregate JSON to stdout");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    anyhow::ensure!(
        !p.get("grid").is_empty(),
        "--grid is required, e.g. --grid \"rate_hz=1e6,5e6;fan_out=1,2\""
    );
    let s = find_scenario(p.get("scenario"))?;
    let mut cfg = load_config(&p, s)?;
    apply_set(&mut cfg, p.get("set"))?;
    let jobs = p.try_u64("jobs").map_err(|e| anyhow::anyhow!("{}", e.0))? as usize;
    let runner = SweepRunner::from_grid(cfg, p.get("grid"))?.jobs(jobs);
    let result = if jobs > 1 {
        // completion order is nondeterministic; result order is not
        runner.run_parallel(s, |done, n| {
            eprintln!("sweep: {done}/{n} points done ({jobs} jobs)");
        })?
    } else {
        runner.run_with_progress(s, |i, n| {
            eprintln!("sweep: point {}/{n}", i + 1);
        })?
    };
    eprintln!(
        "sweep cache: {} prepared, {} reused, {} evicted, {} resident bytes",
        result.cache.misses, result.cache.hits, result.cache.evictions,
        result.cache.resident_bytes
    );
    if !p.get("out").is_empty() {
        std::fs::write(p.get("out"), result.to_json().pretty())?;
        eprintln!("wrote {}", p.get("out"));
    }
    if !p.get("csv").is_empty() {
        std::fs::write(p.get("csv"), result.to_csv())?;
        eprintln!("wrote {}", p.get("csv"));
    }
    if p.flag("json") {
        println!("{}", result.to_json().pretty());
    } else {
        result.table().print();
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("serve", "experiment job server (TCP JSON-lines)")
        .opt("addr", "127.0.0.1:7411", "listen address (port 0 = ephemeral)")
        .opt("workers", "2", "worker-pool size")
        .opt(
            "cache-bytes",
            "0",
            "resource-cache byte budget, LRU-evicted (0 = unbounded)",
        )
        .opt("max-wall-ms", "0", "per-job wall-clock cap in ms (0 = none)")
        .opt("max-events", "0", "per-job simulated-event cap (0 = none)")
        .opt(
            "smoke",
            "0",
            "self-contained smoke mode: bind an ephemeral port, run one \
             in-process loadgen round of N submissions with verification, \
             shut down (exit 0 = healthy)",
        );
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let smoke = p.try_u64("smoke").map_err(|e| anyhow::anyhow!("{}", e.0))? as usize;
    let cfg = ServeConfig {
        addr: if smoke > 0 {
            "127.0.0.1:0".to_string()
        } else {
            p.get("addr").to_string()
        },
        workers: p.try_u64("workers").map_err(|e| anyhow::anyhow!("{}", e.0))? as usize,
        cache_bytes: p
            .try_u64("cache-bytes")
            .map_err(|e| anyhow::anyhow!("{}", e.0))?,
        max_wall_ms: p
            .try_u64("max-wall-ms")
            .map_err(|e| anyhow::anyhow!("{}", e.0))?,
        max_events: p
            .try_u64("max-events")
            .map_err(|e| anyhow::anyhow!("{}", e.0))?,
    };
    let server = Server::bind(cfg)?;
    eprintln!("serve: listening on {}", server.local_addr());
    if smoke == 0 {
        return server.run();
    }
    // smoke mode: one verified in-process loadgen round, then shutdown
    let addr = server.local_addr().to_string();
    let handle = server.spawn();
    let outcome = run_loadgen(&LoadgenConfig {
        addr,
        submissions: smoke,
        verify: true,
        shutdown_after: true,
        ..LoadgenConfig::default()
    })?;
    handle.join()?;
    println!("{}", outcome.to_json().pretty());
    anyhow::ensure!(
        outcome.completed == outcome.submitted,
        "smoke: {} of {} submissions completed",
        outcome.completed,
        outcome.submitted
    );
    anyhow::ensure!(
        outcome.byte_identical(),
        "smoke: {} served reports differ from the batch path",
        outcome.mismatches
    );
    eprintln!(
        "serve smoke: {} submissions ok, reports byte-identical, clean shutdown",
        outcome.completed
    );
    Ok(())
}

fn cmd_loadgen(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("loadgen", "drive a serve instance with concurrent submissions")
        .opt("addr", "127.0.0.1:7411", "server address")
        .opt("submissions", "120", "total submissions")
        .opt("connections", "8", "concurrent pipelined connections")
        .opt(
            "scenarios",
            "traffic,burst,hotspot",
            "comma-separated scenario names cycled across submissions",
        )
        .opt("seed", "1", "arrival/parameter variation seed")
        .opt(
            "base-set",
            bss_extoll::serve::client::default_base_set(),
            "overrides applied to every submission",
        )
        .flag(
            "verify",
            "re-run each unique submission via the batch path and compare bytes",
        )
        .flag("shutdown", "send shutdown to the server when done");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let outcome = run_loadgen(&LoadgenConfig {
        addr: p.get("addr").to_string(),
        submissions: p
            .try_u64("submissions")
            .map_err(|e| anyhow::anyhow!("{}", e.0))? as usize,
        connections: p
            .try_u64("connections")
            .map_err(|e| anyhow::anyhow!("{}", e.0))? as usize,
        scenarios: p
            .get("scenarios")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        seed: p.try_u64("seed").map_err(|e| anyhow::anyhow!("{}", e.0))?,
        base_set: p.get("base-set").to_string(),
        verify: p.flag("verify"),
        shutdown_after: p.flag("shutdown"),
    })?;
    println!("{}", outcome.to_json().pretty());
    Ok(())
}

fn cmd_traffic(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("traffic", "multi-wafer Poisson spike-traffic simulation")
        .opt("config", "", "experiment config JSON (defaults when empty)")
        .opt("rate", "0", "override: events/s per FPGA")
        .opt("duration-ms", "0", "override: simulated duration (ms)")
        .opt("set", "", "config overrides \"key=v;key=v\"")
        .flag("json", "emit the full report as JSON");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let s = find_scenario("traffic")?;
    let mut cfg = load_config(&p, s)?;
    if p.get_f64("rate") > 0.0 {
        cfg.workload.rate_hz = p.get_f64("rate");
    }
    if p.get_f64("duration-ms") > 0.0 {
        cfg.workload.duration =
            bss_extoll::sim::Time::from_secs_f64(p.get_f64("duration-ms") * 1e-3);
    }
    apply_set(&mut cfg, p.get("set"))?;
    let report = s.run(&cfg)?;
    if p.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        report.print();
    }
    Ok(())
}

fn cmd_microcircuit(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "microcircuit",
        "end-to-end multi-wafer cortical microcircuit (LIF neuron shards)",
    )
    .opt("config", "", "experiment config JSON")
    .opt("steps", "0", "override: timesteps")
    .opt("artifact", "", "override: shard artifact name")
    .opt("set", "", "config overrides \"key=v;key=v\"")
    .flag("json", "emit the full report as JSON");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let s = find_scenario("microcircuit")?;
    let mut cfg = load_config(&p, s)?;
    if p.get_u64("steps") > 0 {
        cfg.neuro.steps = p.get_usize("steps");
    }
    if !p.get("artifact").is_empty() {
        cfg.neuro.artifact = p.get("artifact").to_string();
    }
    apply_set(&mut cfg, p.get("set"))?;
    let report = s.run(&cfg)?;
    if p.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        report.print();
    }
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("analyze", "flow-level topology bandwidth analysis (Fig. 1)")
        .opt("wafers", "4", "number of wafer modules")
        .opt("torus", "4x4x2", "torus dimensions XxYxZ")
        .opt("concentrators", "8", "concentrator nodes per wafer")
        .opt("scale", "1.0", "microcircuit scale (1.0 = 77k neurons)")
        .flag("json", "emit the full report as JSON");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let mut cfg = ExperimentConfig::default();
    apply_override(&mut cfg, "n_wafers", p.get("wafers"))?;
    apply_override(&mut cfg, "torus", p.get("torus"))?;
    apply_override(&mut cfg, "concentrators_per_wafer", p.get("concentrators"))?;
    apply_override(&mut cfg, "mc_scale", p.get("scale"))?;
    let report = find_scenario("analyze")?.run(&cfg)?;
    if p.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        report.print();
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("bss-extoll {}", bss_extoll::VERSION);
    let rt = bss_extoll::runtime::Runtime::cpu()?;
    println!("runtime platform: {}", rt.platform());
    let dir = bss_extoll::runtime::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    for name in ["shard_256x1024", "shard_1024x4096"] {
        match rt.load_shard_model(&dir, name) {
            Ok(m) => println!(
                "  {name}: n_local={} n_global={} sha={}",
                m.n_local(),
                m.n_global(),
                &m.manifest.hlo_sha256[..12]
            ),
            Err(_) => println!("  {name}: NOT BUILT (run `make artifacts`)"),
        }
    }
    println!("scenarios: {}", scenario::names().join(", "));
    Ok(())
}
