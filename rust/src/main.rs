//! `bss-extoll` — leader entrypoint for the BrainScaleS-Extoll
//! reproduction: spike-traffic simulations, the multi-wafer cortical
//! microcircuit co-simulation, and flow-level topology analysis.

use anyhow::Result;

use bss_extoll::coordinator::{run_microcircuit, run_traffic, ExperimentConfig};
use bss_extoll::extoll::analysis::FlowAnalysis;
use bss_extoll::extoll::nic::NicConfig;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::sim::Sim;
use bss_extoll::util::args::ArgSpec;
use bss_extoll::util::bench::Table;
use bss_extoll::wafer::system::{System, SystemConfig};
use bss_extoll::workload::microcircuit::{Microcircuit, Placement};

const USAGE: &str = "\
bss-extoll — BrainScaleS large-scale spike communication over Extoll

USAGE:
  bss-extoll <command> [options]   (--help per command)

COMMANDS:
  traffic       multi-wafer Poisson spike-traffic simulation
  microcircuit  end-to-end cortical-microcircuit co-simulation (PJRT)
  analyze       flow-level topology bandwidth analysis (paper Fig. 1)
  info          runtime platform + artifact status
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "traffic" => cmd_traffic(rest),
        "microcircuit" => cmd_microcircuit(rest),
        "analyze" => cmd_analyze(rest),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command '{other}'\n{USAGE}");
        }
    }
}

fn load_config(parsed: &bss_extoll::util::args::Parsed) -> Result<ExperimentConfig> {
    match parsed.get("config") {
        "" => Ok(ExperimentConfig::default()),
        path => ExperimentConfig::from_file(path),
    }
}

fn cmd_traffic(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("traffic", "multi-wafer Poisson spike-traffic simulation")
        .opt("config", "", "experiment config JSON (defaults when empty)")
        .opt("rate", "0", "override: events/s per FPGA")
        .opt("duration-ms", "0", "override: simulated duration (ms)")
        .flag("json", "emit the full report as JSON");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let mut cfg = load_config(&p)?;
    if p.get_f64("rate") > 0.0 {
        cfg.workload.rate_hz = p.get_f64("rate");
    }
    if p.get_f64("duration-ms") > 0.0 {
        cfg.workload.duration =
            bss_extoll::sim::Time::from_secs_f64(p.get_f64("duration-ms") * 1e-3);
    }
    let r = run_traffic(&cfg)?;
    if p.flag("json") {
        println!("{}", r.to_json().pretty());
    } else {
        let mut t = Table::new("traffic report", &["metric", "value"]);
        t.row(vec![
            "events generated".into(),
            r.events_generated.to_string(),
        ]);
        t.row(vec!["events delivered".into(), r.rx_events.to_string()]);
        t.row(vec!["packets".into(), r.packets_out.to_string()]);
        t.row(vec![
            "mean events/packet".into(),
            format!("{:.2}", r.mean_batch),
        ]);
        t.row(vec![
            "flushes (deadline/full/evict)".into(),
            format!("{}/{}/{}", r.flush_deadline, r.flush_full, r.flush_evict),
        ]);
        t.row(vec![
            "latency p50/p99 (ns)".into(),
            format!(
                "{:.0}/{:.0}",
                r.latency.p50() as f64 / 1e3,
                r.latency.p99() as f64 / 1e3
            ),
        ]);
        t.row(vec![
            "deadline misses".into(),
            r.deadline_misses.to_string(),
        ]);
        t.row(vec![
            "peak link util".into(),
            format!("{:.3}", r.max_link_util),
        ]);
        t.row(vec![
            "delivered events/s".into(),
            format!("{:.3e}", r.delivered_events_per_s),
        ]);
        t.print();
    }
    Ok(())
}

fn cmd_microcircuit(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "microcircuit",
        "end-to-end multi-wafer cortical microcircuit (PJRT neuron shards)",
    )
    .opt("config", "", "experiment config JSON")
    .opt("steps", "0", "override: timesteps")
    .opt("artifact", "", "override: shard artifact name")
    .flag("json", "emit the full report as JSON");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let mut cfg = load_config(&p)?;
    if p.get_u64("steps") > 0 {
        cfg.neuro.steps = p.get_usize("steps");
    }
    if !p.get("artifact").is_empty() {
        cfg.neuro.artifact = p.get("artifact").to_string();
    }
    // default system sized for the 4-shard artifacts
    if p.get("config").is_empty() {
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 2,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
    }
    let r = run_microcircuit(&cfg)?;
    if p.flag("json") {
        println!("{}", r.to_json().pretty());
    } else {
        let mut t = Table::new("microcircuit report", &["metric", "value"]);
        t.row(vec!["neurons".into(), r.n_neurons.to_string()]);
        t.row(vec!["shards (FPGAs)".into(), r.n_shards.to_string()]);
        t.row(vec!["steps".into(), r.steps.to_string()]);
        t.row(vec!["spikes".into(), r.spikes_total.to_string()]);
        t.row(vec![
            "mean rate (spk/neuron/step)".into(),
            format!("{:.4}", r.mean_rate),
        ]);
        t.row(vec!["fabric events".into(), r.fabric_events.to_string()]);
        t.row(vec!["delivered".into(), r.delivered_events.to_string()]);
        t.row(vec![
            "mean events/packet".into(),
            format!("{:.2}", r.mean_batch),
        ]);
        t.row(vec![
            "deadline misses".into(),
            r.deadline_misses.to_string(),
        ]);
        t.row(vec![
            "latency p50/p99 (ns)".into(),
            format!(
                "{:.0}/{:.0}",
                r.latency.p50() as f64 / 1e3,
                r.latency.p99() as f64 / 1e3
            ),
        ]);
        t.row(vec![
            "pjrt / des wall (s)".into(),
            format!("{:.2} / {:.2}", r.pjrt_seconds, r.des_seconds),
        ]);
        t.print();
    }
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("analyze", "flow-level topology bandwidth analysis (Fig. 1)")
        .opt("wafers", "4", "number of wafer modules")
        .opt("torus", "4x4x2", "torus dimensions XxYxZ")
        .opt("concentrators", "8", "concentrator nodes per wafer")
        .opt("scale", "1.0", "microcircuit scale (1.0 = 77k neurons)");
    let p = spec.parse(args).map_err(|e| anyhow::anyhow!("{}", e.0))?;
    let dims: Vec<u16> = p
        .get("torus")
        .split('x')
        .map(|s| s.parse().unwrap_or(2))
        .collect();
    anyhow::ensure!(dims.len() == 3, "--torus must be XxYxZ");
    let sys_cfg = SystemConfig {
        n_wafers: p.get_usize("wafers"),
        torus: TorusSpec::new(dims[0], dims[1], dims[2]),
        concentrators_per_wafer: p.get_usize("concentrators"),
        ..SystemConfig::default()
    };
    let mut sim: Sim<bss_extoll::msg::Msg> = Sim::new();
    let sys = System::build(&mut sim, sys_cfg);
    let mc = Microcircuit::new(p.get_f64("scale"));
    let placement = Placement::spread(&mc, &sys);
    let flows = placement.flows(&mc, 32.0);
    let analysis = FlowAnalysis::run(&sys_cfg.torus, &flows, NicConfig::default().link_gbps());
    let mut t = Table::new("topology analysis", &["metric", "value"]);
    t.row(vec!["neurons".into(), mc.total_neurons().to_string()]);
    t.row(vec![
        "total spike rate (ev/s)".into(),
        format!("{:.3e}", mc.total_rate_hz()),
    ]);
    t.row(vec!["fabric flows".into(), flows.len().to_string()]);
    t.row(vec![
        "offered load (Gbit/s)".into(),
        format!("{:.3}", analysis.total_offered_gbps),
    ]);
    t.row(vec![
        "peak link util".into(),
        format!("{:.4}", analysis.max_utilization()),
    ]);
    t.row(vec![
        "mean active link util".into(),
        format!("{:.4}", analysis.mean_active_utilization()),
    ]);
    t.row(vec![
        "sustainable fraction".into(),
        format!("{:.3}", analysis.sustainable_fraction()),
    ]);
    if let Some(((node, dir), load)) = analysis.bottleneck() {
        t.row(vec![
            "bottleneck".into(),
            format!("{node} {dir:?} @ {:.3} Gbit/s", load.gbps),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("bss-extoll {}", bss_extoll::VERSION);
    let rt = bss_extoll::runtime::Runtime::cpu()?;
    println!("pjrt platform: {}", rt.platform());
    let dir = bss_extoll::runtime::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    for name in ["shard_256x1024", "shard_1024x4096"] {
        match rt.load_shard_model(&dir, name) {
            Ok(m) => println!(
                "  {name}: n_local={} n_global={} sha={}",
                m.n_local(),
                m.n_global(),
                &m.manifest.hlo_sha256[..12]
            ),
            Err(_) => println!("  {name}: NOT BUILT (run `make artifacts`)"),
        }
    }
    Ok(())
}
