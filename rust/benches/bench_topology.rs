//! Benchmark F1 + T2 — topology bandwidth utilisation (paper Fig. 1) and
//! the Extoll-vs-GbE comparison.
//!
//! Part 1 (flow-level): concentrators-per-wafer sweep over the full-scale
//! cortical-microcircuit traffic at BrainScaleS acceleration factors.
//! Part 2 (packet-level): a 2-wafer DES run validating the analytic model.
//! Part 3 (T2): the same spike stream over Extoll vs Gigabit-Ethernet.
//!
//! Run: `cargo bench --bench bench_topology`

// The deprecated driver wrappers stay supported for one release.
#![allow(deprecated)]

use bss_extoll::coordinator::{run_traffic, ExperimentConfig};
use bss_extoll::extoll::analysis::FlowAnalysis;
use bss_extoll::extoll::baseline::{GbeConfig, GbeLink};
use bss_extoll::extoll::nic::NicConfig;
use bss_extoll::extoll::packet::Packet;
use bss_extoll::extoll::torus::{NodeAddr, TorusSpec};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Actor, Ctx, Sim, Time};
use bss_extoll::util::bench::{eng, Table};
use bss_extoll::wafer::system::{System, SystemConfig};
use bss_extoll::workload::microcircuit::{Microcircuit, Placement};

fn pick_torus(nodes: usize) -> TorusSpec {
    for &(x, y, z) in &[
        (2u16, 2u16, 1u16),
        (2, 2, 2),
        (4, 2, 2),
        (4, 4, 2),
        (4, 4, 4),
        (8, 4, 4),
        (8, 8, 4),
    ] {
        if (x as usize) * (y as usize) * (z as usize) >= nodes {
            return TorusSpec::new(x, y, z);
        }
    }
    TorusSpec::new(16, 8, 8)
}

fn main() {
    println!("\n==== F1: topology bandwidth utilisation (paper Fig. 1) ====");
    let wafers = 4;
    let mc = Microcircuit::new(1.0);
    for &speedup in &[1e3, 1e4] {
        let mut t = Table::new(
            &format!("concentrators/wafer sweep — {wafers} wafers, 77k-neuron microcircuit, speedup {speedup:.0}x"),
            &[
                "conc/wafer",
                "fpga/conc",
                "torus",
                "offered Gbit/s",
                "peak link util",
                "ingress util",
                "sustainable",
            ],
        );
        for &conc in &[1usize, 2, 4, 8, 16, 48] {
            let torus = pick_torus(wafers * conc);
            let cfg = SystemConfig {
                n_wafers: wafers,
                torus,
                fpgas_per_wafer: 48,
                concentrators_per_wafer: conc,
                ..SystemConfig::default()
            };
            let mut sim: Sim<Msg> = Sim::new();
            let sys = System::build(&mut sim, cfg);
            let placement = Placement::spread(&mc, &sys);
            let flows = placement.flows_accelerated(&mc, 32.0, speedup);
            let nic = NicConfig::default();
            let a = FlowAnalysis::run(&torus, &flows, nic.link_gbps());
            let ingress = a.max_local_utilization(nic.link_gbps());
            let sustainable = a
                .sustainable_fraction()
                .min(1.0 / ingress.max(1e-9))
                .min(1.0);
            t.row(vec![
                conc.to_string(),
                (48 / conc).to_string(),
                format!("{}x{}x{}", torus.nx, torus.ny, torus.nz),
                eng(a.total_offered_gbps),
                format!("{:.4}", a.max_utilization()),
                format!("{:.4}", ingress),
                format!("{:.3}", sustainable),
            ]);
        }
        t.print();
    }
    println!(
        "  paper claim: the 8-concentrator topology is optimal for bandwidth\n\
         utilisation — at speedup 1e3 it is the smallest fan-in whose ingress\n\
         and torus links both stay clear of saturation.\n"
    );

    // ---- packet-level validation (DES) -------------------------------------
    println!("==== packet-level validation: 2 wafers, Poisson uniform traffic ====");
    let mut t = Table::new(
        "DES run vs rate (2 wafers x 6 FPGAs, 2x2 torus)",
        &[
            "rate/FPGA (Mev/s)",
            "delivered ev/s",
            "mean batch",
            "latency p50 (us)",
            "latency p99 (us)",
            "peak link util",
        ],
    );
    for &rate in &[2e6, 10e6, 50e6] {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 6,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = rate;
        cfg.workload.duration = Time::from_ms(1);
        let r = run_traffic(&cfg).expect("traffic run");
        t.row(vec![
            eng(rate / 1e6),
            eng(r.delivered_events_per_s),
            format!("{:.2}", r.mean_batch),
            format!("{:.2}", r.latency.p50() as f64 / 1e6),
            format!("{:.2}", r.latency.p99() as f64 / 1e6),
            format!("{:.4}", r.max_link_util),
        ]);
    }
    t.print();

    // ---- T2: Extoll vs GbE ---------------------------------------------------
    println!("==== T2: Extoll vs Gigabit-Ethernet (the system being replaced) ====");
    struct Sink {
        n: u64,
        last: Time,
    }
    impl Actor<Msg> for Sink {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::Deliver(_) = msg {
                self.n += 1;
                self.last = ctx.now();
            }
        }
    }
    let mut t = Table::new(
        "10k max-size spike packets point-to-point",
        &["fabric", "wire Gbit/s", "kpackets/s", "unloaded latency (us)"],
    );
    // Extoll: 2-node torus — throughput from a saturating burst, latency
    // from an unloaded single packet
    {
        let run = |n: u64| -> (f64, f64) {
            let mut sim: Sim<Msg> = Sim::new();
            let fabric = bss_extoll::extoll::network::Fabric::build(
                &mut sim,
                TorusSpec::new(2, 1, 1),
                NicConfig::default(),
            );
            let sink = sim.add(Sink {
                n: 0,
                last: Time::ZERO,
            });
            sim.get_mut::<bss_extoll::extoll::nic::Nic>(fabric.nics[1]).attach_local(sink);
            for i in 0..n {
                sim.schedule(
                    Time::ZERO,
                    fabric.nics[0],
                    Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, i)),
                );
            }
            sim.run_to_completion();
            let s: &Sink = sim.get(sink);
            (
                s.last.secs_f64(),
                fabric.transit_histogram(&sim).p50() as f64 / 1e6,
            )
        };
        let (secs, _) = run(10_000);
        let (_, lat_unloaded) = run(1);
        t.row(vec![
            "Extoll (12 lanes)".into(),
            format!("{:.2}", 10_000.0 * 520.0 * 8.0 / secs / 1e9),
            format!("{:.0}", 10_000.0 / secs / 1e3),
            format!("{lat_unloaded:.3}"),
        ]);
    }
    // GbE
    {
        let run = |n: u64| -> (f64, f64) {
            let mut sim: Sim<Msg> = Sim::new();
            let link = sim.add(GbeLink::new(GbeConfig::default()));
            let sink = sim.add(Sink {
                n: 0,
                last: Time::ZERO,
            });
            sim.get_mut::<GbeLink>(link).attach_sink(sink);
            for i in 0..n {
                sim.schedule(
                    Time::ZERO,
                    link,
                    Msg::Inject(Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, i)),
                );
            }
            sim.run_to_completion();
            let s: &Sink = sim.get(sink);
            let g: &GbeLink = sim.get(link);
            (s.last.secs_f64(), g.stats.transit_ps.p50() as f64 / 1e6)
        };
        let (secs, _) = run(10_000);
        let (_, lat_unloaded) = run(1);
        t.row(vec![
            "GbE + switch".into(),
            format!("{:.3}", 10_000.0 * (496.0 + 66.0) * 8.0 / secs / 1e9),
            format!("{:.0}", 10_000.0 / secs / 1e3),
            format!("{lat_unloaded:.3}"),
        ]);
    }
    t.print();
    println!("  expected shape: Extoll ≳ 90 Gbit/s and sub-µs latency vs ~1 Gbit/s and >10 µs.\n");
}
