//! Benchmark F2a — ring-buffer host communication (paper §2.1, Fig. 2a).
//!
//! Sweeps ring size and consumer speed, measuring achieved throughput,
//! producer stall behaviour (credit flow control), notification counts,
//! and data latency; then the per-message-handshake baseline the
//! ring-buffer scheme exists to avoid.
//!
//! Run: `cargo bench --bench bench_ringbuffer`

use bss_extoll::extoll::baseline::{GbeConfig, GbeLink};
use bss_extoll::extoll::network::Fabric;
use bss_extoll::extoll::nic::{Nic, NicConfig};
use bss_extoll::extoll::packet::Packet;
use bss_extoll::extoll::torus::{NodeAddr, TorusSpec};
use bss_extoll::host::host::{ChannelConfig, Host, HostConfig};
use bss_extoll::host::stream::{StreamConfig, StreamSource, TIMER_PRODUCE};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Actor, ActorId, Ctx, Sim, Time};
use bss_extoll::util::bench::Table;

fn build(ring: u64, rate: f64, consume: f64, total: u64) -> (Sim<Msg>, ActorId, ActorId) {
    let mut sim: Sim<Msg> = Sim::new();
    let fabric = Fabric::build(&mut sim, TorusSpec::new(2, 1, 1), NicConfig::default());
    let stream = sim.add(StreamSource::new(StreamConfig {
        node: NodeAddr(0),
        host_node: NodeAddr(1),
        ring_size: ring,
        rate_bps: rate,
        total_bytes: total,
        ..StreamConfig::default()
    }));
    let host = sim.add(Host::new(HostConfig {
        node: NodeAddr(1),
        consume_rate: consume,
        ..HostConfig::default()
    }));
    {
        let h = sim.get_mut::<Host>(host);
        h.attach_nic(fabric.nics[1]);
        h.add_channel(ChannelConfig {
            id: 1,
            nla_base: 0x10000,
            ring_size: ring,
            producer_node: NodeAddr(0),
            credit_batch: ring / 4,
        });
    }
    sim.get_mut::<StreamSource>(stream).attach_nic(fabric.nics[0]);
    sim.get_mut::<Nic>(fabric.nics[0]).attach_local(stream);
    sim.get_mut::<Nic>(fabric.nics[1]).attach_local(host);
    sim.schedule(Time::ZERO, stream, Msg::Timer(TIMER_PRODUCE));
    (sim, stream, host)
}

fn main() {
    println!("\n==== F2a: ring-buffer host communication (paper §2.1) ====");
    let total = 2u64 << 20;

    // ---- ring-size sweep -----------------------------------------------------
    let mut t = Table::new(
        "ring-size sweep (producer 4 GB/s, consumer unbounded, 2 MiB transferred)",
        &[
            "ring KiB",
            "achieved Gbit/s",
            "stall episodes",
            "stall time",
            "notifications",
            "credits",
            "latency p50 (us)",
        ],
    );
    for &ring in &[1u64 << 13, 1 << 14, 1 << 16, 1 << 18] {
        let (mut sim, stream, host) = build(ring, 4e9, 0.0, total);
        sim.run(400_000_000);
        assert_eq!(sim.pending(), 0, "run did not converge");
        let s: &StreamSource = sim.get(stream);
        let h: &Host = sim.get(host);
        assert_eq!(h.stats.bytes_consumed, total, "data loss");
        t.row(vec![
            (ring >> 10).to_string(),
            format!("{:.2}", total as f64 * 8.0 / sim.now.secs_f64() / 1e9),
            s.stats.stall_episodes.to_string(),
            format!("{}", s.stats.stall_time),
            h.stats.notifications.to_string(),
            h.stats.credits_sent.to_string(),
            format!("{:.1}", h.stats.data_latency_ps.p50() as f64 / 1e6),
        ]);
    }
    t.print();

    // ---- consumer-speed sweep -------------------------------------------------
    let mut t = Table::new(
        "consumer-speed sweep (64 KiB ring, producer 4 GB/s)",
        &[
            "consumer MB/s",
            "achieved Gbit/s",
            "stall episodes",
            "producer stalled %",
        ],
    );
    for &consume in &[0.0, 2e9, 500e6, 100e6] {
        let (mut sim, stream, host) = build(1 << 16, 4e9, consume, total);
        sim.run(400_000_000);
        let s: &StreamSource = sim.get(stream);
        let h: &Host = sim.get(host);
        assert_eq!(h.stats.bytes_consumed, total, "data loss");
        let label = if consume == 0.0 {
            "unbounded".to_string()
        } else {
            format!("{:.0}", consume / 1e6)
        };
        t.row(vec![
            label,
            format!("{:.2}", total as f64 * 8.0 / sim.now.secs_f64() / 1e9),
            s.stats.stall_episodes.to_string(),
            format!(
                "{:.1}",
                s.stats.stall_time.ps() as f64 / sim.now.ps() as f64 * 100.0
            ),
        ]);
    }
    t.print();
    println!(
        "  reading: credit flow control throttles the producer exactly to the\n\
         consumer's speed — no loss, no overrun, stalls grow as the consumer\n\
         slows (Fig. 2a's write-pointer/space-register scheme).\n"
    );

    // ---- handshake baseline -----------------------------------------------------
    struct CountSink {
        bytes: u64,
        last: Time,
    }
    impl Actor<Msg> for CountSink {
        fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::Deliver(p) = msg {
                self.bytes += p.payload_bytes as u64;
                self.last = ctx.now();
            }
        }
    }
    let mut t = Table::new(
        "ring-buffer vs per-message handshake (1 KiB messages over GbE; ring over Extoll)",
        &["scheme", "achieved Gbit/s"],
    );
    for (label, handshake) in [("GbE streaming", false), ("GbE handshake/msg", true)] {
        let mut sim: Sim<Msg> = Sim::new();
        let link = sim.add(GbeLink::new(GbeConfig {
            handshake,
            ..GbeConfig::default()
        }));
        let sink = sim.add(CountSink {
            bytes: 0,
            last: Time::ZERO,
        });
        sim.get_mut::<GbeLink>(link).attach_sink(sink);
        for i in 0..2048u64 {
            sim.schedule(
                Time::ZERO,
                link,
                Msg::Inject(Packet::raw_gbe(NodeAddr(0), NodeAddr(1), 1024, Time::ZERO, i)),
            );
        }
        sim.run(100_000_000);
        let s: &CountSink = sim.get(sink);
        t.row(vec![
            label.to_string(),
            format!("{:.3}", s.bytes as f64 * 8.0 / s.last.secs_f64() / 1e9),
        ]);
    }
    // the Extoll ring from above, fast path
    {
        let (mut sim, _, host) = build(1 << 16, 40e9, 0.0, total);
        sim.run(400_000_000);
        let h: &Host = sim.get(host);
        t.row(vec![
            "Extoll ring buffer".to_string(),
            format!(
                "{:.2}",
                h.stats.bytes_consumed as f64 * 8.0 / sim.now.secs_f64() / 1e9
            ),
        ]);
    }
    t.print();
}
