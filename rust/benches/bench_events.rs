//! Perf-trajectory benchmark (see PERF.md): A/B of the event-queue
//! backends (binary heap vs calendar wheel), serial-vs-parallel sweep
//! execution, PDES domain scaling, PDES sync-protocol scaling (windowed
//! global-minimum vs per-neighbor channel clocks vs barrier-free), the
//! sweep-level resource cache (prepare-once vs per-point cold runs), and
//! packet-payload pooling.
//!
//! `make bench-json` runs this and writes the machine-readable artifact
//! `BENCH_PR10.json` at the repo root (path comes from `BSS_BENCH_JSON`;
//! without it, e.g. under a generic `cargo bench`, nothing is written so
//! the committed full-mode artifact cannot be clobbered by fast-mode
//! numbers): per-bench ns/op and events/s for heap vs wheel, wall-clock
//! and speedup for `sweep --jobs {1,2,4}`, events/s at `domains=1/2/4`
//! with a report-identity check against the serial run,
//! window/channel/free events/s at `domains=2/4/8` on a 16-node torus,
//! cached-sweep speedup +
//! hit/miss counters for traffic and microcircuit, pool-on/off events/s
//! with a byte-identity check, and the degraded-fabric deliverability
//! curve (`fault_sweep` over rising failed-cable fractions, with a
//! cross-domain identity check under faults), and the link-reliability
//! recovery curve (`reliability_sweep` over loss rates × off/link, with
//! deliverability pinned at exactly 1.0 whenever the layer is on and a
//! cross-domain identity check with retransmission timers live), and the
//! service-mode throughput round (`serve_throughput`: an in-process
//! `serve` instance driven by the `loadgen` client with 100+ concurrent
//! mixed-scenario submissions — submissions/s, p50/p95 turnaround,
//! cache prepared-vs-reused counters, and a byte-identity check of
//! every served report against the batch `run` path), and the rack
//! scaling curve (`rack_scaling`: the `microcircuit_rack` scenario at
//! 4/8/20 wafers — events/s, prepared-plan resident bytes and wire
//! bytes per neuron, with the `reuse=fabric` rewound execute timed
//! against a cold rebuild and checked byte-identical). The CI
//! `bench-smoke` job re-runs
//! it with `BSS_BENCH_FAST=1`, fails on any `SKIPPED` row, and validates
//! the artifact shape with `scripts/validate_bench.py`, so this artifact
//! cannot silently rot.

use std::time::Instant;

use bss_extoll::coordinator::scenario::{find, Scenario};
use bss_extoll::coordinator::sweep::{apply_override, SweepRunner};
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::packet::pool;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::serve::client::{run_loadgen, LoadgenConfig};
use bss_extoll::serve::{ServeConfig, Server};
use bss_extoll::sim::{EventQueue, QueueKind, SyncMode, Time};
use bss_extoll::util::bench::{eng, fast_mode, BenchSuite, Table};
use bss_extoll::util::json::Json;
use bss_extoll::util::rng::Rng;
use bss_extoll::wafer::system::SystemConfig;

/// Pure queue hold-pattern: pop one event, push one ~Poisson-spaced
/// replacement. Exactly the access pattern the DES inner loop produces.
fn bench_queue_transit(suite: &mut BenchSuite, kind: QueueKind, resident: usize) {
    let mut q = EventQueue::<u64>::with_capacity(kind, resident + 1);
    let mut rng = Rng::new(0xB55);
    let mut now = Time::ZERO;
    for i in 0..resident {
        q.push(now + Time::from_ps(rng.below(2_000_000)), 0, i as u64);
    }
    suite.bench_items(
        &format!("transit/{}/{}k-resident", kind.as_str(), resident / 1000),
        1.0,
        move || {
            let ev = q.pop().expect("hold pattern never empties");
            now = ev.at;
            q.push(now + Time::from_ps(1 + rng.below(2_000_000)), 0, ev.msg);
        },
    );
}

/// Traffic scenario sized so one run is seconds-scale (fast: sub-second).
fn traffic_base(fast: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 2,
        torus: TorusSpec::new(2, 2, 1),
        fpgas_per_wafer: 4,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.workload.rate_hz = 2e7;
    cfg.workload.sources_per_fpga = 64;
    cfg.workload.duration = if fast {
        Time::from_us(300)
    } else {
        Time::from_ms(2)
    };
    cfg
}

/// Best-of-`reps` measurement of one scenario config: (DES events
/// dispatched, best wall seconds, pretty report JSON of the last rep).
/// Every event-loop section (heap/wheel A/B, PDES domain and sync
/// scaling, packet pooling) measures through this one helper so the
/// protocol (rep count, best-of selection) can never drift apart
/// between sections.
fn timed_runs(scenario: &dyn Scenario, cfg: &ExperimentConfig, reps: u32) -> (u64, f64, String) {
    let mut best_wall = f64::INFINITY;
    let mut events = 0u64;
    let mut json = String::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        let report = scenario.run(cfg).expect("bench scenario run failed");
        let wall = t0.elapsed().as_secs_f64();
        events = report
            .get_count("des_events")
            .expect("des_events metric missing");
        json = report.to_json().pretty();
        if wall < best_wall {
            best_wall = wall;
        }
    }
    (events, best_wall, json)
}

/// The `eviction_ablation` base config, trimmed so a grid point stays
/// seconds-scale (relative job scaling is what the artifact tracks).
fn sweep_base(fast: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::from_file("configs/eviction_ablation.json")
        .expect("configs/eviction_ablation.json");
    cfg.system.fpgas_per_wafer = if fast { 8 } else { 16 };
    cfg.workload.sources_per_fpga = if fast { 16 } else { 64 };
    cfg.workload.duration = if fast {
        Time::from_us(200)
    } else {
        Time::from_ms(1)
    };
    cfg
}

fn main() {
    let fast = fast_mode();
    let reps = if fast { 2 } else { 3 };

    // ---- 1. pure queue ops: heap vs wheel --------------------------------
    let mut suite = BenchSuite::new("event-queue transit (pop+push)");
    suite.header();
    for resident in [4_096usize, 65_536] {
        for kind in [QueueKind::Heap, QueueKind::Wheel] {
            bench_queue_transit(&mut suite, kind, resident);
        }
    }
    suite.finish();

    // ---- 2. traffic-scenario event loop: heap vs wheel --------------------
    let base = traffic_base(fast);
    let traffic = find("traffic").expect("traffic registered");
    let mut loop_runs = Json::arr();
    let mut loop_table = Table::new(
        "traffic-scenario event loop",
        &["queue", "des_events", "wall_s", "events/s"],
    );
    let mut events_per_s = [0.0f64; 2];
    for (ki, kind) in [QueueKind::Heap, QueueKind::Wheel].into_iter().enumerate() {
        let mut cfg = base.clone();
        cfg.queue = kind;
        let (events, best_wall, _) = timed_runs(traffic, &cfg, reps);
        let eps = events as f64 / best_wall;
        events_per_s[ki] = eps;
        loop_table.row(vec![
            kind.as_str().to_string(),
            events.to_string(),
            format!("{best_wall:.3}"),
            eng(eps),
        ]);
        loop_runs.push(
            Json::obj()
                .set("queue", kind.as_str())
                .set("des_events", events)
                .set("wall_s", best_wall)
                .set("events_per_s", eps),
        );
    }
    let wheel_vs_heap = events_per_s[1] / events_per_s[0];
    loop_table.print();
    println!("wheel vs heap: {wheel_vs_heap:.2}x events/s\n");

    // ---- 3. sweep scaling: serial vs parallel -----------------------------
    let grid = "eviction=most_urgent,fullest,oldest,round_robin";
    let sweep_cfg = sweep_base(fast);
    let mut sweep_runs = Json::arr();
    let mut sweep_table = Table::new(
        "eviction_ablation sweep scaling",
        &["jobs", "points", "wall_s", "speedup"],
    );
    let mut wall_serial = 0.0f64;
    let mut csv_serial = String::new();
    let mut deterministic = true;
    for jobs in [1usize, 2, 4] {
        let runner = SweepRunner::from_grid(sweep_cfg.clone(), grid)
            .expect("sweep grid")
            .jobs(jobs);
        let t0 = Instant::now();
        let result = runner.run(traffic).expect("sweep run failed");
        let wall = t0.elapsed().as_secs_f64();
        let csv = result.to_csv();
        if jobs == 1 {
            wall_serial = wall;
            csv_serial = csv.clone();
        } else if csv != csv_serial {
            deterministic = false;
        }
        let speedup = wall_serial / wall;
        sweep_table.row(vec![
            jobs.to_string(),
            result.points.len().to_string(),
            format!("{wall:.3}"),
            format!("{speedup:.2}"),
        ]);
        sweep_runs.push(
            Json::obj()
                .set("jobs", jobs)
                .set("n_points", result.points.len())
                .set("wall_s", wall)
                .set("speedup_vs_serial", speedup),
        );
    }
    sweep_table.print();
    assert!(deterministic, "parallel sweep CSV diverged from serial");

    // ---- 4. PDES domain scaling: one scenario on N domains -----------------
    // Bigger machine than the heap/wheel A/B so each conservative window
    // (one lookahead ≈ 75 ns of simulated time) carries enough events to
    // amortize the barrier: 4 wafers on a 2x2x2 torus.
    let mut pdes_cfg = traffic_base(fast);
    pdes_cfg.system.n_wafers = 4;
    pdes_cfg.system.torus = TorusSpec::new(2, 2, 2);
    pdes_cfg.system.fpgas_per_wafer = 8;
    let mut pdes_runs = Json::arr();
    let mut pdes_table = Table::new(
        "PDES domain scaling (traffic scenario, wheel queue)",
        &["domains", "des_events", "wall_s", "events/s", "speedup"],
    );
    let mut serial_eps = 0.0f64;
    let mut serial_json = String::new();
    let mut pdes_deterministic = true;
    let mut multi_domain_best_eps = 0.0f64;
    for domains in [1usize, 2, 4] {
        let mut cfg = pdes_cfg.clone();
        cfg.domains = domains;
        let (events, best_wall, json) = timed_runs(traffic, &cfg, reps);
        let eps = events as f64 / best_wall;
        if domains == 1 {
            serial_eps = eps;
            serial_json = json;
        } else {
            if json != serial_json {
                pdes_deterministic = false;
            }
            if eps > multi_domain_best_eps {
                multi_domain_best_eps = eps;
            }
        }
        let speedup = eps / serial_eps;
        pdes_table.row(vec![
            domains.to_string(),
            events.to_string(),
            format!("{best_wall:.3}"),
            eng(eps),
            format!("{speedup:.2}"),
        ]);
        pdes_runs.push(
            Json::obj()
                .set("domains", domains)
                .set("des_events", events)
                .set("wall_s", best_wall)
                .set("events_per_s", eps)
                .set("speedup_vs_serial", speedup),
        );
    }
    pdes_table.print();
    println!(
        "best multi-domain vs serial: {:.2}x events/s\n",
        multi_domain_best_eps / serial_eps
    );
    assert!(pdes_deterministic, "PDES report diverged from serial");

    // ---- 4b. PDES sync-protocol scaling: window vs channel vs free ---------
    // A larger torus than the domain-scaling section (16 nodes, 8 wafers)
    // so the domain adjacency graph has real diameter at domains >= 4 —
    // that is where channel clocks discount far-apart domains by several
    // hops of accumulated lookahead and the global-minimum window pays.
    let mut sync_cfg = traffic_base(fast);
    sync_cfg.system.n_wafers = 8;
    sync_cfg.system.torus = TorusSpec::new(4, 2, 2);
    sync_cfg.system.fpgas_per_wafer = 8;
    sync_cfg.system.concentrators_per_wafer = 2;
    let mut sync_runs = Json::arr();
    let mut sync_table = Table::new(
        "PDES sync scaling (traffic scenario, 4x2x2 torus, wheel queue)",
        &["sync", "domains", "des_events", "wall_s", "events/s", "speedup"],
    );
    let mut sync_deterministic = true;
    // events/s per (sync, domains) cell
    let mut cell_eps: Vec<((SyncMode, usize), f64)> = Vec::new();
    let (sync_serial_eps, sync_serial_json) = {
        let mut cfg = sync_cfg.clone();
        cfg.domains = 1;
        let (events, best_wall, json) = timed_runs(traffic, &cfg, reps);
        let eps = events as f64 / best_wall;
        sync_table.row(vec![
            "serial".to_string(),
            "1".to_string(),
            events.to_string(),
            format!("{best_wall:.3}"),
            eng(eps),
            "1.00".to_string(),
        ]);
        sync_runs.push(
            Json::obj()
                .set("sync", "serial")
                .set("domains", 1u64)
                .set("des_events", events)
                .set("wall_s", best_wall)
                .set("events_per_s", eps)
                .set("speedup_vs_serial", 1.0),
        );
        (eps, json)
    };
    for sync in SyncMode::ALL {
        for domains in [2usize, 4, 8] {
            let mut cfg = sync_cfg.clone();
            cfg.sync = sync;
            cfg.domains = domains;
            let (events, best_wall, json) = timed_runs(traffic, &cfg, reps);
            if json != sync_serial_json {
                sync_deterministic = false;
            }
            let eps = events as f64 / best_wall;
            cell_eps.push(((sync, domains), eps));
            let speedup = eps / sync_serial_eps;
            sync_table.row(vec![
                sync.as_str().to_string(),
                domains.to_string(),
                events.to_string(),
                format!("{best_wall:.3}"),
                eng(eps),
                format!("{speedup:.2}"),
            ]);
            sync_runs.push(
                Json::obj()
                    .set("sync", sync.as_str())
                    .set("domains", domains as u64)
                    .set("des_events", events)
                    .set("wall_s", best_wall)
                    .set("events_per_s", eps)
                    .set("speedup_vs_serial", speedup),
            );
        }
    }
    let cell = |sync: SyncMode, domains: usize| -> f64 {
        cell_eps
            .iter()
            .find(|(k, _)| *k == (sync, domains))
            .map(|&(_, eps)| eps)
            .expect("sync cell recorded")
    };
    let channel_vs_window_4 = cell(SyncMode::Channel, 4) / cell(SyncMode::Window, 4);
    let free_vs_channel_4 = cell(SyncMode::Free, 4) / cell(SyncMode::Channel, 4);
    sync_table.print();
    println!("channel vs window at 4 domains: {channel_vs_window_4:.2}x events/s");
    println!("free vs channel at 4 domains: {free_vs_channel_4:.2}x events/s\n");
    assert!(sync_deterministic, "PDES sync report diverged from serial");

    // ---- 5. sweep resource cache: prepare-once vs per-point cold runs ------
    // A/B the PR 4 two-phase lifecycle: "uncached" evaluates every grid
    // point with scenario.run() (prepare per point — the pre-redesign
    // behaviour); "cached" runs the same grid through SweepRunner, whose
    // ResourceCache shares one prepare across points with equal cache
    // keys. Byte-identity of cached vs cold point data is pinned in
    // rust/tests/determinism_queue.rs; here we only track wall-clock.
    fn cache_bench(
        table: &mut Table,
        scenario: &'static dyn Scenario,
        base: &ExperimentConfig,
        axis_key: &str,
        axis_vals: &[&str],
    ) -> Json {
        use bss_extoll::coordinator::sweep::apply_override;
        let t0 = Instant::now();
        let mut cold_reports = Vec::new();
        for v in axis_vals {
            let mut cfg = base.clone();
            apply_override(&mut cfg, axis_key, v).expect("axis override");
            cold_reports.push(scenario.run(&cfg).expect("uncached run failed"));
        }
        let wall_uncached = t0.elapsed().as_secs_f64();

        let runner = SweepRunner::new(base.clone()).axis(axis_key, axis_vals);
        let t0 = Instant::now();
        let result = runner.run(scenario).expect("cached sweep failed");
        let wall_cached = t0.elapsed().as_secs_f64();
        for (cold, point) in cold_reports.iter().zip(&result.points) {
            assert_eq!(
                cold.scenario(),
                point.report.scenario(),
                "cache A/B compared different scenarios"
            );
        }
        let speedup = wall_uncached / wall_cached;
        table.row(vec![
            scenario.name().to_string(),
            result.points.len().to_string(),
            format!("{wall_uncached:.3}"),
            format!("{wall_cached:.3}"),
            format!("{speedup:.2}"),
            format!("{}/{}", result.cache.misses, result.cache.hits),
        ]);
        Json::obj()
            .set("n_points", result.points.len())
            .set("wall_uncached_s", wall_uncached)
            .set("wall_cached_s", wall_cached)
            .set("speedup", speedup)
            .set("cache_misses", result.cache.misses)
            .set("cache_hits", result.cache.hits)
    }
    let mut cache_section = Json::obj();
    let mut cache_table = Table::new(
        "sweep resource cache (uncached = per-point run())",
        &["scenario", "points", "uncached_s", "cached_s", "speedup", "miss/hit"],
    );
    let traffic_cache = cache_bench(
        &mut cache_table,
        traffic,
        &sweep_base(fast),
        "rate_hz",
        &["1e7", "1.5e7", "2e7", "2.5e7"],
    );
    cache_section.insert("traffic", traffic_cache);
    if bss_extoll::runtime::artifacts_available() {
        let mc = find("microcircuit").expect("microcircuit registered");
        let mc_base = mc.default_config();
        let steps: &[&str] = if fast {
            &["2", "3", "4", "5"]
        } else {
            &["5", "10", "15", "20"]
        };
        let mc_cache = cache_bench(&mut cache_table, mc, &mc_base, "steps", steps);
        cache_section.insert("microcircuit", mc_cache);
    } else {
        println!("  sweep-cache/microcircuit SKIPPED: artifacts not built (make artifacts)");
    }
    cache_table.print();

    // ---- 6. packet-payload pooling: free-list reuse A/B ---------------------
    // extoll::packet::pool closes the flush→RX allocation loop; reports
    // must be byte-identical with the pool off (the determinism gate in
    // rust/tests/determinism_queue.rs pins the same invariant).
    let pool_base = traffic_base(fast);
    let mut pool_table = Table::new(
        "packet-payload pooling (traffic scenario)",
        &["pool", "des_events", "wall_s", "events/s"],
    );
    let mut pool_eps = [0.0f64; 2];
    let mut pool_json = [String::new(), String::new()];
    let mut pool_counts = (0u64, 0u64);
    for (pi, enabled) in [false, true].into_iter().enumerate() {
        pool::set_enabled(enabled);
        pool::reset_stats();
        let (events, best_wall, json) = timed_runs(traffic, &pool_base, reps);
        pool_json[pi] = json;
        if enabled {
            pool_counts = pool::stats();
        }
        let eps = events as f64 / best_wall;
        pool_eps[pi] = eps;
        pool_table.row(vec![
            if enabled { "on" } else { "off" }.to_string(),
            events.to_string(),
            format!("{best_wall:.3}"),
            eng(eps),
        ]);
    }
    pool::set_enabled(true);
    let pool_deterministic = pool_json[0] == pool_json[1];
    let pool_speedup = pool_eps[1] / pool_eps[0];
    pool_table.print();
    println!("pool on vs off: {pool_speedup:.2}x events/s\n");
    assert!(pool_deterministic, "packet pooling changed observable results");

    // ---- 7. fault sweep: degraded-fabric deliverability curve ---------------
    // Deliverability is exactly 1.0 on the healthy fabric and monotone
    // non-increasing in the failed-cable fraction (the curve's shape is
    // policed by scripts/validate_bench.py), and faulted reports stay
    // byte-identical across PDES domain counts (the PR 6 determinism
    // gate in rust/tests/determinism_queue.rs pins the same invariant).
    let fault_scn = find("fault_sweep").expect("fault_sweep registered");
    let fault_base = traffic_base(fast);
    let mut fault_runs = Json::arr();
    let mut fault_table = Table::new(
        "fault sweep (traffic workload, degraded fabric)",
        &["fault", "failed_cables", "deliverability", "hop_inflation", "wall_s"],
    );
    let mut prev_deliv = f64::INFINITY;
    for spec in ["none", "fail:0.2", "fail:0.45"] {
        let mut cfg = fault_base.clone();
        apply_override(&mut cfg, "fault", spec).expect("fault spec");
        let t0 = Instant::now();
        let report = fault_scn.run(&cfg).expect("fault_sweep run failed");
        let wall = t0.elapsed().as_secs_f64();
        let deliv = report.get_f64("deliverability").expect("deliverability");
        let inflation = report.get_f64("hop_inflation").expect("hop_inflation");
        let failed = report.get_count("failed_cables").expect("failed_cables");
        assert!(
            deliv <= prev_deliv,
            "deliverability rose as the failed-cable fraction grew"
        );
        prev_deliv = deliv;
        fault_table.row(vec![
            spec.to_string(),
            failed.to_string(),
            format!("{deliv:.4}"),
            format!("{inflation:.3}"),
            format!("{wall:.3}"),
        ]);
        fault_runs.push(
            Json::obj()
                .set("fault", spec)
                .set("failed_cables", failed)
                .set("deliverability", deliv)
                .set("hop_inflation", inflation)
                .set("wall_s", wall),
        );
    }
    let mut faulted = fault_base.clone();
    apply_override(&mut faulted, "fault", "fail:0.2|loss:0.01|jitter_ns:25")
        .expect("fault spec");
    let fault_serial = fault_scn.run(&faulted).expect("faulted run").to_json().pretty();
    faulted.domains = 2;
    let fault_partitioned = fault_scn.run(&faulted).expect("faulted run").to_json().pretty();
    let fault_deterministic = fault_serial == fault_partitioned;
    fault_table.print();
    assert!(
        fault_deterministic,
        "faulted reports diverged across PDES domain counts"
    );

    // ---- 8. reliability sweep: retransmission recovery economics ------------
    // With reliability=link every CRC-dropped packet is recovered within
    // the retry budget: deliverability is pinned at exactly 1.0 with zero
    // residual loss, at a measured events/s cost; reliability=off
    // reproduces the lossy fault_sweep curve. Reports with the layer on
    // stay byte-identical across PDES domain counts (the PR 7 determinism
    // gate in rust/tests/determinism_queue.rs pins the same invariant).
    let rel_scn = find("reliability_sweep").expect("reliability_sweep registered");
    let rel_base = traffic_base(fast);
    let mut rel_runs = Json::arr();
    let mut rel_table = Table::new(
        "reliability sweep (lossy fabric, link-level ACK/NACK retransmission)",
        &["reliability", "fault", "deliverability", "retx", "residual", "events/s", "wall_s"],
    );
    // events/s per (mode, spec) cell, for the zero-loss overhead ratio
    let mut rel_eps: Vec<((String, String), f64)> = Vec::new();
    for spec in ["none", "loss:0.01", "loss:0.03"] {
        let mut off_deliv = 1.0f64;
        for mode in ["off", "link"] {
            let mut cfg = rel_base.clone();
            apply_override(&mut cfg, "fault", spec).expect("fault spec");
            apply_override(&mut cfg, "reliability", mode).expect("reliability mode");
            let t0 = Instant::now();
            let report = rel_scn.run(&cfg).expect("reliability_sweep run failed");
            let wall = t0.elapsed().as_secs_f64();
            let deliv = report.get_f64("deliverability").expect("deliverability");
            let retx = report.get_count("retransmissions").expect("retransmissions");
            let residual = report
                .get_count("residual_loss_events")
                .expect("residual_loss_events");
            let events = report.get_count("des_events").expect("des_events");
            let eps = events as f64 / wall;
            rel_eps.push(((mode.to_string(), spec.to_string()), eps));
            if mode == "off" {
                off_deliv = deliv;
                assert_eq!(retx, 0, "retransmissions without the layer ({spec})");
            } else {
                assert_eq!(
                    deliv, 1.0,
                    "reliability=link must recover every event ({spec})"
                );
                assert_eq!(residual, 0, "residual loss below the retry limit ({spec})");
                assert!(
                    deliv >= off_deliv,
                    "link deliverability below the off curve ({spec})"
                );
            }
            rel_table.row(vec![
                mode.to_string(),
                spec.to_string(),
                format!("{deliv:.4}"),
                retx.to_string(),
                residual.to_string(),
                eng(eps),
                format!("{wall:.3}"),
            ]);
            rel_runs.push(
                Json::obj()
                    .set("reliability", mode)
                    .set("fault", spec)
                    .set("deliverability", deliv)
                    .set("crc_failures", report.get_count("crc_failures").unwrap_or(0))
                    .set("retransmissions", retx)
                    .set("residual_loss_events", residual)
                    .set("des_events", events)
                    .set("wall_s", wall)
                    .set("events_per_s", eps),
            );
        }
    }
    let rel_cell = |mode: &str, spec: &str| -> f64 {
        rel_eps
            .iter()
            .find(|((m, s), _)| m == mode && s == spec)
            .map(|&(_, eps)| eps)
            .expect("reliability cell recorded")
    };
    let link_vs_off_at_zero_loss = rel_cell("link", "none") / rel_cell("off", "none");
    let mut rel_det_cfg = rel_base.clone();
    apply_override(&mut rel_det_cfg, "fault", "loss:0.02|jitter_ns:25").expect("fault spec");
    apply_override(&mut rel_det_cfg, "reliability", "link").expect("reliability mode");
    let rel_serial = rel_scn.run(&rel_det_cfg).expect("reliable run").to_json().pretty();
    rel_det_cfg.domains = 2;
    let rel_partitioned = rel_scn.run(&rel_det_cfg).expect("reliable run").to_json().pretty();
    let rel_deterministic = rel_serial == rel_partitioned;
    rel_table.print();
    println!("link vs off events/s at zero loss: {link_vs_off_at_zero_loss:.2}x\n");
    assert!(
        rel_deterministic,
        "reliable reports diverged across PDES domain counts"
    );

    // ---- 9. service mode: job-server throughput -----------------------------
    // An in-process `serve` instance (4 workers, 1 MiB cache budget)
    // driven by the `loadgen` client: 120 mixed-scenario submissions
    // pipelined down 8 connections. `verify` re-runs every unique
    // submission through the batch `Scenario::run` path and compares
    // the served report bytes — the acceptance gate tying service mode
    // to the repo's determinism invariant. The budget is deliberately
    // generous here (eviction-under-pressure correctness is pinned in
    // rust/tests/serve_mode.rs): a thrashing cache would break the
    // prepared < submissions sharing claim this section tracks.
    let serve_submissions = 120usize;
    let serve_connections = 8usize;
    let serve_budget: u64 = 1 << 20;
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        cache_bytes: serve_budget,
        max_wall_ms: 0,
        max_events: 0,
    })
    .expect("bind serve bench server");
    let serve_addr = server.local_addr().to_string();
    let handle = server.spawn();
    let outcome = run_loadgen(&LoadgenConfig {
        addr: serve_addr,
        submissions: serve_submissions,
        connections: serve_connections,
        verify: true,
        shutdown_after: true,
        ..LoadgenConfig::default()
    })
    .expect("serve loadgen round");
    handle.join().expect("serve server shutdown");
    assert_eq!(
        outcome.completed, serve_submissions as u64,
        "every serve submission must complete"
    );
    assert!(
        outcome.byte_identical(),
        "{} served reports differ from the batch path",
        outcome.mismatches
    );
    let serve_json = outcome.to_json();
    let serve_prepared = serve_json.at(&["cache", "prepared"]).and_then(Json::as_u64);
    let serve_resident = serve_json
        .at(&["cache", "resident_bytes"])
        .and_then(Json::as_u64);
    if let Some(prepared) = serve_prepared {
        assert!(
            prepared < serve_submissions as u64,
            "cross-submission cache never shared ({prepared} prepares)"
        );
    }
    if let Some(resident) = serve_resident {
        assert!(
            resident <= serve_budget,
            "cache resident bytes {resident} exceed the {serve_budget}-byte budget"
        );
    }
    let mut serve_table = Table::new(
        "serve throughput (4 workers, 8 connections, 1 MiB cache)",
        &["submissions", "completed", "subs/s", "p50_us", "p95_us", "prepared/reused"],
    );
    serve_table.row(vec![
        outcome.submitted.to_string(),
        outcome.completed.to_string(),
        format!("{:.1}", outcome.subs_per_s()),
        outcome.turnaround_us.p50().to_string(),
        outcome.turnaround_us.quantile(0.95).to_string(),
        format!(
            "{}/{}",
            serve_prepared.unwrap_or(0),
            serve_json.at(&["cache", "reused"]).and_then(Json::as_u64).unwrap_or(0)
        ),
    ]);
    serve_table.print();
    let serve_section = serve_json
        .set("workers", 4u64)
        .set("connections", serve_connections)
        .set("cache_budget_bytes", serve_budget);

    // ---- 10. rack scaling: fabric reuse at 4/8/20 wafers --------------------
    // The PR 10 tentpole economics: at rack scale the dominant per-point
    // cost is building thousands of boxed actors, which `reuse=fabric`
    // replaces with a `Sim::reset_to_epoch` rewind. Cold (reuse=off)
    // vs warm (rewound) wall-clock per wafer count, with the reports
    // checked byte-identical; resident_bytes is the prepared plan's
    // cache charge, bytes_per_neuron the paper's wire-cost figure.
    let rack_scn = find("microcircuit_rack").expect("microcircuit_rack registered");
    let mut rack_runs = Json::arr();
    let mut rack_table = Table::new(
        "rack scaling (microcircuit_rack, warm rewind vs cold rebuild)",
        &["wafers", "fpgas", "des_events", "cold_s", "warm_s", "reuse_speedup", "events/s", "resident_B"],
    );
    let mut rack_deterministic = true;
    for (wafers, torus) in [
        (4usize, TorusSpec::new(4, 4, 2)),
        (8, TorusSpec::new(4, 4, 4)),
        (20, TorusSpec::new(8, 5, 4)),
    ] {
        let mut cfg = rack_scn.default_config();
        cfg.system.n_wafers = wafers;
        cfg.system.torus = torus;
        cfg.workload.duration = if fast {
            Time::from_us(20)
        } else {
            Time::from_us(200)
        };
        let mut cold_cfg = cfg.clone();
        apply_override(&mut cold_cfg, "reuse", "off").expect("reuse override");
        let t0 = Instant::now();
        let cold_report = rack_scn.run(&cold_cfg).expect("rack cold run failed");
        let wall_cold = t0.elapsed().as_secs_f64();
        // park a fabric, then time the rewound execute
        rack_scn.run(&cfg).expect("rack warm-up run failed");
        let t0 = Instant::now();
        let warm_report = rack_scn.run(&cfg).expect("rack warm run failed");
        let wall_warm = t0.elapsed().as_secs_f64();
        if cold_report.to_json().pretty() != warm_report.to_json().pretty() {
            rack_deterministic = false;
        }
        let events = warm_report.get_count("des_events").expect("des_events");
        let eps = events as f64 / wall_warm;
        let resident = warm_report.get_count("resident_bytes").expect("resident_bytes");
        let bpn = warm_report.get_f64("bytes_per_neuron").expect("bytes_per_neuron");
        let n_fpgas = wafers * cfg.system.fpgas_per_wafer;
        let reuse_speedup = wall_cold / wall_warm;
        rack_table.row(vec![
            wafers.to_string(),
            n_fpgas.to_string(),
            events.to_string(),
            format!("{wall_cold:.3}"),
            format!("{wall_warm:.3}"),
            format!("{reuse_speedup:.2}"),
            eng(eps),
            resident.to_string(),
        ]);
        rack_runs.push(
            Json::obj()
                .set("wafers", wafers)
                .set("n_fpgas", n_fpgas)
                .set("des_events", events)
                .set("wall_cold_s", wall_cold)
                .set("wall_warm_s", wall_warm)
                .set("reuse_speedup", reuse_speedup)
                .set("events_per_s", eps)
                .set("resident_bytes", resident)
                .set("bytes_per_neuron", bpn),
        );
    }
    rack_table.print();
    assert!(
        rack_deterministic,
        "fabric reuse changed the rack report"
    );

    // ---- artifact ----------------------------------------------------------
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let doc = Json::obj()
        .set("schema", "bss-extoll-bench/1")
        .set("artifact", "BENCH_PR10")
        .set("fast", fast)
        .set("threads_available", threads)
        .set("queue_transit", suite.to_json())
        .set(
            "traffic_event_loop",
            Json::obj()
                .set("runs", loop_runs)
                .set("wheel_vs_heap_speedup", wheel_vs_heap),
        )
        .set(
            "sweep_scaling",
            Json::obj()
                .set("grid", grid)
                .set("deterministic_across_jobs", deterministic)
                .set("runs", sweep_runs),
        )
        .set(
            "pdes_domain_scaling",
            Json::obj()
                .set("deterministic_across_domains", pdes_deterministic)
                .set(
                    "multi_domain_vs_serial_speedup",
                    multi_domain_best_eps / serial_eps,
                )
                .set("runs", pdes_runs),
        )
        .set(
            "pdes_sync_scaling",
            Json::obj()
                .set("deterministic_across_modes", sync_deterministic)
                .set("channel_vs_window_at_4_domains", channel_vs_window_4)
                .set("free_vs_channel_at_4_domains", free_vs_channel_4)
                .set("runs", sync_runs),
        )
        .set("sweep_cache", cache_section)
        .set(
            "packet_pooling",
            Json::obj()
                .set("deterministic_pool_on_off", pool_deterministic)
                .set("events_per_s_pool_off", pool_eps[0])
                .set("events_per_s_pool_on", pool_eps[1])
                .set("speedup", pool_speedup)
                .set("buffers_recycled", pool_counts.0)
                .set("buffers_fresh", pool_counts.1),
        )
        .set(
            "fault_sweep",
            Json::obj()
                .set("deterministic_across_domains", fault_deterministic)
                .set("runs", fault_runs),
        )
        .set(
            "reliability_sweep",
            Json::obj()
                .set("deterministic_across_domains", rel_deterministic)
                .set("link_vs_off_at_zero_loss", link_vs_off_at_zero_loss)
                .set("runs", rel_runs),
        )
        .set("serve_throughput", serve_section)
        .set(
            "rack_scaling",
            Json::obj()
                .set("deterministic_reuse_vs_rebuild", rack_deterministic)
                .set("runs", rack_runs),
        );
    // Only write when explicitly asked (make bench-json sets the path):
    // a generic `cargo bench` / `make bench` run must not clobber the
    // committed full-mode trajectory artifact with fast-mode numbers.
    match std::env::var("BSS_BENCH_JSON") {
        Ok(path) => {
            std::fs::write(&path, doc.pretty()).expect("write bench artifact");
            println!("\nwrote {path}");
        }
        Err(_) => println!("\nBSS_BENCH_JSON not set — artifact not written (use `make bench-json`)"),
    }
}
