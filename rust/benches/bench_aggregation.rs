//! Benchmark F2b + T1 — event aggregation (paper §3.1, Fig. 2b).
//!
//! Regenerates the paper's aggregation argument:
//! - single 30-bit events ship at ≤ 1 event / 2 clocks (header overhead),
//! - bucket aggregation reaches up to 124 events per 496-byte packet,
//! - deadline-triggered flushing bounds event latency,
//! - concurrent flush/aggregation (dual counters) vs the blocking ablation.
//!
//! Run: `cargo bench --bench bench_aggregation` (BSS_BENCH_FAST=1 to trim).

use bss_extoll::extoll::packet::MAX_EVENTS_PER_PACKET;
use bss_extoll::extoll::torus::NodeAddr;
use bss_extoll::fpga::bucket::BucketConfig;
use bss_extoll::fpga::event::{RoutedEvent, SpikeEvent};
use bss_extoll::fpga::fpga::{Fpga, FpgaConfig};
use bss_extoll::fpga::lookup::{EndpointAddr, TxEntry};
use bss_extoll::fpga::manager::{BucketManager, EvictionPolicy, ManagerConfig};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Actor, ActorId, Ctx, Sim, Time};
use bss_extoll::util::bench::{eng, BenchSuite, Table};
use bss_extoll::util::rng::Rng;

/// Uplink stub: counts packets/events, returns inject credits immediately.
struct Uplink {
    fpga: ActorId,
    packets: u64,
    events: u64,
    bytes: u64,
}

impl Actor<Msg> for Uplink {
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if let Msg::Inject(p) = msg {
            self.packets += 1;
            self.events += p.n_events() as u64;
            self.bytes += p.wire_bytes() as u64;
            ctx.send(self.fpga, Time::ZERO, Msg::Credit { port: 6, vc: 0 });
        }
    }
}

/// One simulated aggregation run: Poisson events at `rate_hz` to
/// `n_dests` destinations for `dur`; returns (packets, events, bytes,
/// p50_wait_ns, p99_wait_ns, stalled, dropped).
fn run_once(
    rate_hz: f64,
    n_dests: usize,
    capacity: usize,
    margin: u16,
    concurrent: bool,
    dur: Time,
) -> (u64, u64, u64, f64, f64, u64, u64) {
    let mut sim: Sim<Msg> = Sim::new();
    let cfg = FpgaConfig {
        manager: ManagerConfig {
            n_buckets: 32,
            bucket: BucketConfig {
                capacity,
                deadline_margin: margin,
                concurrent,
            },
            eviction: EvictionPolicy::MostUrgent,
        },
        ..FpgaConfig::default()
    };
    let fpga = sim.add(Fpga::new(cfg));
    let uplink = sim.add(Uplink {
        fpga,
        packets: 0,
        events: 0,
        bytes: 0,
    });
    sim.get_mut::<Fpga>(fpga).attach_uplink(uplink);
    for d in 0..n_dests {
        sim.get_mut::<Fpga>(fpga).tx_lut.set(
            (d % 8) as u8,
            (d / 8) as u16,
            TxEntry {
                dest: EndpointAddr::new(NodeAddr(1 + d as u16), 0),
                guid: d as u16,
            },
        );
    }
    // Poisson arrivals, deadline = arrival + 2100 cycles (10 µs)
    let mut rng = Rng::new(7);
    let mut t = 0.0f64;
    let end = dur.secs_f64();
    while t < end {
        t += rng.exponential(rate_hz);
        let at = Time::from_secs_f64(t);
        let d = rng.index(n_dests);
        let deadline =
            ((bss_extoll::fpga::event::systime_of(at) as u32 + 2100) & 0x7FFF) as u16;
        sim.schedule(
            at,
            fpga,
            Msg::HicannEvent(SpikeEvent::new((d % 8) as u8, (d / 8) as u16, deadline)),
        );
    }
    sim.run_until(dur + Time::from_us(50));
    sim.schedule(
        sim.now,
        fpga,
        Msg::Timer(bss_extoll::fpga::fpga::TIMER_FLUSH_ALL),
    );
    sim.run_to_completion();
    let f: &Fpga = sim.get(fpga);
    let u: &Uplink = sim.get(uplink);
    (
        u.packets,
        u.events,
        u.bytes,
        f.stats.bucket_wait_ps.p50() as f64 / 1e3,
        f.stats.bucket_wait_ps.p99() as f64 / 1e3,
        f.stats.stalled_events,
        f.stats.dropped_events,
    )
}

fn main() {
    println!("\n==== F2b: event aggregation (paper §3.1, Fig. 2b) ====");

    // ---- rate sweep: aggregation efficiency vs offered load --------------
    let dur = Time::from_ms(2);
    let mut t = Table::new(
        "aggregation efficiency vs event rate (32 buckets, cap 124, margin 420 cyc, 8 dests)",
        &[
            "rate (Mev/s)",
            "events",
            "packets",
            "ev/packet",
            "wire B/event",
            "egress cyc/event",
            "wait p50 (ns)",
            "wait p99 (ns)",
        ],
    );
    for &rate in &[1e6, 5e6, 20e6, 50e6, 100e6, 200e6] {
        let (packets, events, bytes, p50, p99, _, _) =
            run_once(rate, 8, MAX_EVENTS_PER_PACKET, 420, true, dur);
        let cyc_per_event = (bytes as f64 / 8.0) / events as f64; // 64-bit words/event
        t.row(vec![
            eng(rate / 1e6),
            events.to_string(),
            packets.to_string(),
            format!("{:.2}", events as f64 / packets as f64),
            format!("{:.2}", bytes as f64 / events as f64),
            format!("{:.2}", cyc_per_event),
            eng(p50),
            eng(p99),
        ]);
    }
    t.print();

    // ---- baseline: single-event messages (capacity 1) --------------------
    let mut t = Table::new(
        "aggregated vs single-event messages at 100 Mev/s (T1: the 1-event-per-2-clocks limit)",
        &["mode", "ev/packet", "egress cyc/event", "stalled", "dropped"],
    );
    for (label, cap) in [("single-event (no aggregation)", 1), ("buckets cap 124", 124)] {
        let (packets, events, bytes, _, _, stalled, dropped) =
            run_once(100e6, 8, cap, 420, true, dur);
        t.row(vec![
            label.to_string(),
            format!("{:.2}", events as f64 / packets.max(1) as f64),
            format!("{:.2}", (bytes as f64 / 8.0) / events.max(1) as f64),
            stalled.to_string(),
            dropped.to_string(),
        ]);
    }
    t.print();
    println!(
        "  paper: single events ≤ 1 per 2 clocks (≥2 cyc/event incl. header);\n\
         aggregated: 124 events in 65 words ≈ 0.52 cyc/event — a ~10x win.\n"
    );

    // ---- deadline sweep: latency bound vs margin --------------------------
    let mut t = Table::new(
        "deadline-margin sweep at 5 Mev/s (latency bounded by flush deadline)",
        &[
            "margin (cycles)",
            "margin (ns)",
            "ev/packet",
            "wait p50 (ns)",
            "wait p99 (ns)",
        ],
    );
    for &margin in &[105u16, 420, 1050, 2100] {
        let (packets, events, _, p50, p99, _, _) = run_once(5e6, 8, 124, margin, true, dur);
        t.row(vec![
            margin.to_string(),
            format!("{:.0}", margin as f64 * 4.76),
            format!("{:.2}", events as f64 / packets as f64),
            eng(p50),
            eng(p99),
        ]);
    }
    t.print();

    // ---- concurrent flush ablation ----------------------------------------
    let mut t = Table::new(
        "concurrent flush/aggregation (dual counters) vs blocking ablation, 200 Mev/s into 1 dest",
        &["mode", "ev/packet", "stalled", "dropped", "wait p99 (ns)"],
    );
    for (label, conc) in [("concurrent (paper)", true), ("blocking (ablation)", false)] {
        let (packets, events, _, _, p99, stalled, dropped) =
            run_once(200e6, 1, 124, 420, conc, dur);
        t.row(vec![
            label.to_string(),
            format!("{:.2}", events as f64 / packets.max(1) as f64),
            stalled.to_string(),
            dropped.to_string(),
            eng(p99),
        ]);
    }
    t.print();

    // ---- hot-path microbenchmarks ------------------------------------------
    let mut suite = BenchSuite::new("aggregation hot path");
    suite.header();
    let dest = EndpointAddr::new(NodeAddr(3), 1);
    let mut mgr = BucketManager::new(ManagerConfig::default());
    let mut ts = 0u16;
    suite.bench("manager.insert (map hit, no flush)", || {
        ts = (ts + 1) & 0x7FFF;
        let r = mgr.insert(dest, RoutedEvent::new(1, ts, Time::ZERO));
        for b in r.batches {
            mgr.drain_complete(b.bucket_idx);
        }
    });
    let mut mgr2 = BucketManager::new(ManagerConfig {
        n_buckets: 8,
        ..ManagerConfig::default()
    });
    let mut d = 0u16;
    suite.bench("manager.insert (renaming, 64 dests / 8 buckets)", || {
        d = (d + 1) % 64;
        ts = (ts + 1) & 0x7FFF;
        let r = mgr2.insert(
            EndpointAddr::new(NodeAddr(d), 0),
            RoutedEvent::new(1, ts, Time::ZERO),
        );
        for b in r.batches {
            mgr2.drain_complete(b.bucket_idx);
        }
    });
    suite.finish();
}
