//! Benchmark F2c — bucket management (paper §3.1, Fig. 2c).
//!
//! The map table + free-bucket list + arbiter under renaming pressure:
//! sweep the number of live destinations against the physical bucket pool,
//! compare destination popularity distributions (uniform vs Zipf) and the
//! four eviction policies for "the next appropriate one".
//!
//! Run: `cargo bench --bench bench_bucket_mgmt`

use bss_extoll::fpga::bucket::BucketConfig;
use bss_extoll::fpga::event::RoutedEvent;
use bss_extoll::fpga::lookup::EndpointAddr;
use bss_extoll::fpga::manager::{BucketManager, EvictionPolicy, ManagerConfig};
use bss_extoll::sim::Time;
use bss_extoll::util::bench::{eng, BenchSuite, Table};
use bss_extoll::util::rng::{Rng, Zipf};

/// Drive `n_events` into a manager; returns (mean batch, evictions/kev,
/// renames, deadline flushes) — deadlines scanned every 64 events.
fn drive(
    n_buckets: usize,
    n_dests: usize,
    zipf_s: f64,
    policy: EvictionPolicy,
    n_events: u64,
) -> (f64, f64, u64, u64) {
    let mut mgr = BucketManager::new(ManagerConfig {
        n_buckets,
        bucket: BucketConfig {
            capacity: 124,
            deadline_margin: 420,
            concurrent: true,
        },
        eviction: policy,
    });
    let mut rng = Rng::new(1234);
    let zipf = Zipf::new(n_dests, zipf_s);
    let mut flushed_events = 0u64;
    let mut flushed_batches = 0u64;
    let mut now: u16 = 0;
    for i in 0..n_events {
        now = ((i / 4) & 0x7FFF) as u16; // systime advances 1 per 4 events
        // spread over the full 16-bit destination space (10b node + 6b fpga)
        let dest = EndpointAddr::from_u16(zipf.sample(&mut rng) as u16);
        let deadline = (now as u32 + 2100) as u16 & 0x7FFF;
        let r = mgr.insert(dest, RoutedEvent::new(1, deadline, Time::ZERO));
        for b in r.batches {
            flushed_events += b.events.len() as u64;
            flushed_batches += 1;
            mgr.drain_complete(b.bucket_idx);
        }
        if i % 64 == 0 {
            for b in mgr.poll_deadlines(now) {
                flushed_events += b.events.len() as u64;
                flushed_batches += 1;
                mgr.drain_complete(b.bucket_idx);
            }
        }
    }
    for b in mgr.flush_all() {
        flushed_events += b.events.len() as u64;
        flushed_batches += 1;
    }
    assert_eq!(flushed_events, n_events, "event conservation");
    (
        flushed_events as f64 / flushed_batches.max(1) as f64,
        mgr.stats.evictions as f64 * 1000.0 / n_events as f64,
        mgr.stats.renames,
        mgr.stats.flush_deadline,
    )
}

fn main() {
    println!("\n==== F2c: bucket management — map table / free list / arbiter ====");
    let n_events = 200_000u64;

    // ---- destination-count sweep ------------------------------------------
    let mut t = Table::new(
        "destinations vs physical buckets (uniform traffic, most-urgent eviction)",
        &[
            "dests",
            "buckets",
            "ev/batch",
            "evictions/kev",
            "renames",
            "deadline flushes",
        ],
    );
    for &n_dests in &[4usize, 16, 64, 256, 1024, 4096] {
        for &n_buckets in &[8usize, 32, 128] {
            let (batch, evk, renames, dl) =
                drive(n_buckets, n_dests, 0.0, EvictionPolicy::MostUrgent, n_events);
            t.row(vec![
                n_dests.to_string(),
                n_buckets.to_string(),
                format!("{batch:.2}"),
                format!("{evk:.2}"),
                renames.to_string(),
                dl.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "  reading: once live destinations ≫ buckets, renaming churns\n\
         (evictions cut small batches). Skewed traffic recovers efficiency\n\
         because hot destinations keep their buckets.\n"
    );

    // ---- popularity skew ----------------------------------------------------
    let mut t = Table::new(
        "destination popularity (1024 dests, 32 buckets)",
        &["zipf s", "ev/batch", "evictions/kev"],
    );
    for &s in &[0.0, 0.8, 1.2, 2.0] {
        let (batch, evk, _, _) = drive(32, 1024, s, EvictionPolicy::MostUrgent, n_events);
        t.row(vec![format!("{s:.1}"), format!("{batch:.2}"), format!("{evk:.2}")]);
    }
    t.print();

    // ---- eviction policy ablation -------------------------------------------
    let mut t = Table::new(
        "eviction policy ablation (256 dests, 32 buckets, zipf 0.8)",
        &["policy", "ev/batch", "evictions/kev", "deadline flushes"],
    );
    for (name, p) in [
        ("most-urgent (paper arbiter)", EvictionPolicy::MostUrgent),
        ("fullest", EvictionPolicy::Fullest),
        ("oldest", EvictionPolicy::Oldest),
        ("round-robin", EvictionPolicy::RoundRobin),
    ] {
        let (batch, evk, _, dl) = drive(32, 256, 0.8, p, n_events);
        t.row(vec![
            name.to_string(),
            format!("{batch:.2}"),
            format!("{evk:.2}"),
            dl.to_string(),
        ]);
    }
    t.print();

    // ---- throughput microbenchmark ------------------------------------------
    let mut suite = BenchSuite::new("bucket-manager throughput");
    suite.header();
    for &(dests, buckets) in &[(8usize, 32usize), (256, 32), (4096, 32)] {
        let mut mgr = BucketManager::new(ManagerConfig {
            n_buckets: buckets,
            ..ManagerConfig::default()
        });
        let mut rng = Rng::new(9);
        let mut i = 0u64;
        suite.bench_items(
            &format!("insert+flush ({dests} dests, {buckets} buckets)"),
            1.0,
            move || {
                i += 1;
                let dest = EndpointAddr::from_u16(rng.below(dests as u64) as u16);
                let ts = ((i / 4) & 0x7FFF) as u16;
                let r = mgr.insert(dest, RoutedEvent::new(1, ts, Time::ZERO));
                for b in r.batches {
                    mgr.drain_complete(b.bucket_idx);
                }
            },
        );
    }
    suite.finish();
}
