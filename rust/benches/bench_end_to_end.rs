//! Benchmark E2E — the full stack: AOT LIF shards (PJRT) × aggregation ×
//! torus fabric, on the down-scaled cortical microcircuit (paper §4).
//!
//! Reports steps/s, the PJRT vs DES wall-time split, fabric behaviour
//! (aggregation, latency, loss), plus a PJRT step microbenchmark.
//! Requires `make artifacts`; prints SKIPPED rows when absent.
//!
//! Run: `cargo bench --bench bench_end_to_end`

// The deprecated driver wrappers stay supported for one release.
#![allow(deprecated)]

use bss_extoll::coordinator::{run_microcircuit, ExperimentConfig};
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::runtime::{artifacts_available, artifacts_dir, Runtime};
use bss_extoll::util::bench::{BenchSuite, Table};
use bss_extoll::wafer::system::SystemConfig;

fn main() {
    println!("\n==== E2E: multi-wafer cortical microcircuit (paper §4) ====");
    if !artifacts_available() {
        println!("SKIPPED: artifacts not built — run `make artifacts` first");
        return;
    }
    let fast = std::env::var("BSS_BENCH_FAST").is_ok();
    let steps = if fast { 50 } else { 200 };

    let mut t = Table::new(
        "end-to-end co-simulation",
        &[
            "artifact",
            "neurons",
            "steps",
            "spikes",
            "steps/s",
            "pjrt s",
            "des s",
            "ev/packet",
            "fabric p99 (ns)",
            "loss",
        ],
    );
    for artifact in ["shard_256x1024", "shard_1024x4096"] {
        if fast && artifact == "shard_1024x4096" {
            continue;
        }
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 2,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.neuro.artifact = artifact.to_string();
        cfg.neuro.steps = steps;
        let wall = std::time::Instant::now();
        let r = run_microcircuit(&cfg).expect("e2e run");
        let secs = wall.elapsed().as_secs_f64();
        t.row(vec![
            artifact.to_string(),
            r.n_neurons.to_string(),
            r.steps.to_string(),
            r.spikes_total.to_string(),
            format!("{:.1}", r.steps as f64 / secs),
            format!("{:.2}", r.pjrt_seconds),
            format!("{:.2}", r.des_seconds),
            format!("{:.2}", r.mean_batch),
            format!("{:.0}", r.latency.p99() as f64 / 1e3),
            (r.fabric_events - r.delivered_events).to_string(),
        ]);
    }
    t.print();

    // ---- PJRT step microbenchmark ---------------------------------------------
    let mut suite = BenchSuite::new("PJRT shard step (hot path)");
    suite.header();
    let rt = Runtime::cpu().expect("pjrt client");
    for artifact in ["shard_256x1024", "shard_1024x4096"] {
        if fast && artifact == "shard_1024x4096" {
            continue;
        }
        let model = rt
            .load_shard_model(&artifacts_dir(), artifact)
            .expect("artifact");
        let n_local = model.n_local();
        let n_global = model.n_global();
        let state = vec![0.1f32; 3 * n_local];
        let spikes = vec![0.0f32; n_global];
        let w = vec![0.001f32; n_local * n_global];
        {
            let model = rt
                .load_shard_model(&artifacts_dir(), artifact)
                .expect("artifact");
            let state = state.clone();
            let spikes = spikes.clone();
            let w = w.clone();
            suite.bench_items(
                &format!("{artifact}.step literal-upload ({n_local} neurons)"),
                n_local as f64,
                move || {
                    let _ = model.step(&state, &spikes, &w).unwrap();
                },
            );
        }
        let w_buf = model.upload_weights(&w).expect("upload");
        suite.bench_items(
            &format!("{artifact}.step_with cached-W ({n_local} neurons)"),
            n_local as f64,
            move || {
                let _ = model.step_with(&state, &spikes, &w_buf).unwrap();
            },
        );
    }
    suite.finish();
}
