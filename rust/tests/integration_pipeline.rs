//! Integration tests: the complete spike pipeline across wafers —
//! generators → FPGA TX (lookup, buckets) → concentrators → torus →
//! FPGA RX (GUID multicast) — including determinism and failure injection.

use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::fpga::event::SpikeEvent;
use bss_extoll::fpga::fpga::Fpga;
use bss_extoll::fpga::lookup::{RxEntry, TxEntry};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Sim, Time};
use bss_extoll::util::rng::Rng;
use bss_extoll::wafer::system::{System, SystemConfig};
use bss_extoll::workload::generators::{GenConfig, PoissonGen};
use bss_extoll::workload::trace::{Trace, TraceReplay};

fn small_system(sim: &mut Sim<Msg>) -> System {
    System::build(
        sim,
        SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        },
    )
}

/// Route every (hicann, pulse<32) source of every FPGA to one fixed
/// partner on the other wafer.
fn program_pair_routes(sim: &mut Sim<Msg>, sys: &System) {
    let n = sys.n_fpgas();
    for src in 0..n {
        let dst = (src + n / 2) % n; // wafer 0 ↔ wafer 1
        let (sw, ss) = (src / 4, src % 4);
        let (dw, ds) = (dst / 4, dst % 4);
        for h in 0..8u8 {
            for pulse in 0..4u16 {
                let guid = (src * 32 + (h as usize) * 4 + pulse as usize) as u16;
                sys.program_route(sim, (sw, ss), h, pulse, (dw, ds), guid, 0xFF, pulse);
            }
        }
    }
}

#[test]
fn poisson_pipeline_no_loss_across_wafers() {
    let mut sim = Sim::new();
    let sys = small_system(&mut sim);
    program_pair_routes(&mut sim, &sys);
    let mut rng = Rng::new(55);
    let mut gens = Vec::new();
    for (_, _, actor, _) in sys.fpgas() {
        let sources: Vec<(u8, u16)> = (0..8).flat_map(|h| (0..4).map(move |p| (h, p))).collect();
        let g = sim.add(PoissonGen::new(
            GenConfig {
                sources,
                rate_hz: 5e6,
                deadline_offset: 2100,
                until: Some(Time::from_us(500)),
                ..GenConfig::default()
            },
            actor,
            rng.next_u64(),
        ));
        sim.schedule(Time::ZERO, g, Msg::Timer(0));
        gens.push(g);
    }
    sim.run_until(Time::from_ms(1));
    sys.flush_all(&mut sim);
    sim.run_until(Time::from_ms(2));

    let generated: u64 = gens
        .iter()
        .map(|&g| sim.get::<PoissonGen>(g).stats.generated)
        .sum();
    assert!(generated > 10_000, "generated only {generated}");
    assert_eq!(sys.total_events_in(&sim), generated);
    assert_eq!(sys.total_events_out(&sim), generated, "events stuck in buckets");
    assert_eq!(sys.total_rx_events(&sim), generated, "events lost in fabric");
    // aggregation must be active at 5 Mev/s
    assert!(sys.mean_batch_size(&sim) > 2.0);
}

#[test]
fn trace_replay_is_bit_deterministic() {
    // identical trace replays must produce identical system statistics
    let mut trace = Trace::new();
    let mut rng = Rng::new(9);
    let mut t = Time::ZERO;
    for _ in 0..500 {
        t += Time::from_ns(rng.range(10, 500));
        let deadline = ((bss_extoll::fpga::event::systime_of(t) as u32 + 2100) & 0x7FFF) as u16;
        trace.push(
            t,
            SpikeEvent::new(rng.below(8) as u8, rng.below(4) as u16, deadline),
        );
    }
    let run = |trace: Trace| -> (u64, u64, u64) {
        let mut sim = Sim::new();
        let sys = small_system(&mut sim);
        program_pair_routes(&mut sim, &sys);
        let target = sys.wafers[0].fpgas[0];
        let rep = sim.add(TraceReplay::new(trace, target));
        sim.schedule(Time::ZERO, rep, Msg::Timer(0));
        sim.run_until(Time::from_ms(1));
        sys.flush_all(&mut sim);
        sim.run_until(Time::from_ms(2));
        (
            sys.total_rx_events(&sim),
            sys.total_packets_out(&sim),
            sim.processed(),
        )
    };
    let a = run(trace.clone());
    let b = run(trace);
    assert_eq!(a, b, "non-deterministic replay");
    assert_eq!(a.0, 500);
}

#[test]
fn unrouted_events_counted_not_crashing() {
    let mut sim = Sim::new();
    let sys = small_system(&mut sim);
    // no routes programmed at all
    let target = sys.wafers[0].fpgas[0];
    for i in 0..100u64 {
        sim.schedule(
            Time::from_ns(i * 100),
            target,
            Msg::HicannEvent(SpikeEvent::new(0, 99, 1000)),
        );
    }
    sim.run_to_completion();
    let f: &Fpga = sim.get(target);
    assert_eq!(f.stats.tx_unrouted, 100);
    assert_eq!(sys.total_packets_out(&sim), 0);
}

#[test]
fn rx_guid_miss_counted() {
    let mut sim = Sim::new();
    let sys = small_system(&mut sim);
    // program only TX; RX side misses the GUID
    let src_actor = sys.wafers[0].fpgas[0];
    let dst_ep = sys.wafers[1].endpoints[1];
    sim.get_mut::<Fpga>(src_actor).tx_lut.set(
        0,
        7,
        TxEntry {
            dest: dst_ep,
            guid: 777,
        },
    );
    sim.schedule(
        Time::ZERO,
        src_actor,
        Msg::HicannEvent(SpikeEvent::new(0, 7, 500)),
    );
    sim.run_until(Time::from_ms(1));
    let dst: &Fpga = sim.get(sys.wafers[1].fpgas[1]);
    assert_eq!(dst.stats.rx_events, 1);
    assert_eq!(dst.stats.playback.unrouted, 1);
    assert_eq!(dst.stats.playback.total_delivered(), 0);
}

#[test]
fn multicast_mask_fans_out_to_hicanns() {
    let mut sim = Sim::new();
    let sys = small_system(&mut sim);
    let src_actor = sys.wafers[0].fpgas[0];
    let dst_ep = sys.wafers[1].endpoints[0];
    sim.get_mut::<Fpga>(src_actor).tx_lut.set(
        1,
        3,
        TxEntry {
            dest: dst_ep,
            guid: 42,
        },
    );
    let dst_actor = sys.wafers[1].fpgas[0];
    sim.get_mut::<Fpga>(dst_actor).rx_lut.set(
        42,
        RxEntry {
            hicann_mask: 0xFF, // all 8
            pulse_addr: 0x10,
        },
    );
    sim.schedule(
        Time::ZERO,
        src_actor,
        Msg::HicannEvent(SpikeEvent::new(1, 3, 2100)),
    );
    sim.run_until(Time::from_ms(1));
    let dst: &Fpga = sim.get(dst_actor);
    assert_eq!(dst.stats.playback.total_delivered(), 8, "8-way multicast");
    for h in 0..8 {
        assert_eq!(dst.stats.playback.per_hicann[h], 1);
    }
}

#[test]
fn fan_out_to_three_wafer_destinations() {
    let mut sim = Sim::new();
    let sys = small_system(&mut sim);
    let src_actor = sys.wafers[0].fpgas[0];
    // one source, three destinations on the other wafer
    for (i, slot) in [0usize, 1, 2].iter().enumerate() {
        let dst_ep = sys.wafers[1].endpoints[*slot];
        sim.get_mut::<Fpga>(src_actor).tx_lut.add(
            2,
            9,
            TxEntry {
                dest: dst_ep,
                guid: 100 + i as u16,
            },
        );
        sim.get_mut::<Fpga>(sys.wafers[1].fpgas[*slot]).rx_lut.set(
            100 + i as u16,
            RxEntry {
                hicann_mask: 1,
                pulse_addr: 0,
            },
        );
    }
    sim.schedule(
        Time::ZERO,
        src_actor,
        Msg::HicannEvent(SpikeEvent::new(2, 9, 2100)),
    );
    sim.run_until(Time::from_ms(1));
    for slot in [0usize, 1, 2] {
        let f: &Fpga = sim.get(sys.wafers[1].fpgas[slot]);
        assert_eq!(f.stats.rx_events, 1, "fpga {slot} missed the fan-out copy");
    }
    let src: &Fpga = sim.get(src_actor);
    assert_eq!(src.stats.events_in, 1);
    assert_eq!(src.stats.events_out, 3, "one event → three wire events");
}
