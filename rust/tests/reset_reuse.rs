//! PR 10 gates for the rack-scale memory refactor: `Sim::reset_to_epoch`
//! fabric rewind (`reuse=fabric`) must be observationally invisible —
//! every fabric scenario, on both queue backends, produces byte-identical
//! reports whether the fabric is rewound from the pool or cold-rebuilt —
//! and the SoA arenas must keep handles stable (rows never move) so
//! prepared resources survive any number of executes.
//!
//! The cross-mode (sync × domains) face of the same cube lives in
//! `rust/tests/differential_sync.rs` (the `reuse` axis of
//! [`support::DiffMatrix`]); these tests pin the reuse contract itself,
//! including hand-rolled property sweeps over random machine shapes.

#[path = "support/mod.rs"]
mod support;

use bss_extoll::coordinator::config::ReuseMode;
use bss_extoll::coordinator::scenario::find;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::sim::{Arena, F32Arena, QueueKind, Time};
use bss_extoll::util::rng::Rng;
use support::small;

/// Run `scenario` under `cfg`; returns the pretty report JSON.
fn run_json(scenario: &str, cfg: &ExperimentConfig) -> String {
    find(scenario)
        .unwrap_or_else(|| panic!("scenario {scenario} not registered"))
        .run(cfg)
        .unwrap_or_else(|e| panic!("{scenario} run failed: {e:#}"))
        .to_json()
        .pretty()
}

/// Warm reruns (first run parks the fabric, later runs rewind it) match
/// a cold rebuild byte-for-byte — for every fabric scenario, on both
/// queue backends.
#[test]
fn reset_equals_rebuild_per_scenario() {
    for scenario in ["traffic", "burst", "hotspot", "microcircuit_rack"] {
        for kind in [QueueKind::Wheel, QueueKind::Heap] {
            let mut warm = small();
            warm.queue = kind;
            assert_eq!(warm.reuse, ReuseMode::Fabric, "fabric reuse must be the default");
            let first = run_json(scenario, &warm); // cold: pool is empty or key-mismatched
            let second = run_json(scenario, &warm); // rewinds the fabric parked by `first`
            let third = run_json(scenario, &warm);
            let mut cold = warm.clone();
            cold.reuse = ReuseMode::Off;
            let rebuilt = run_json(scenario, &cold);
            assert_eq!(first, second, "{scenario}/{kind:?}: first warm rerun diverged");
            assert_eq!(first, third, "{scenario}/{kind:?}: second warm rerun diverged");
            assert_eq!(first, rebuilt, "{scenario}/{kind:?}: reuse diverged from rebuild");
        }
    }
}

/// Property sweep: random machine shapes, seeds and workloads — the
/// rewound fabric must restore clock, queue, per-actor stats and
/// sequence counters exactly, or these byte-level comparisons fail.
#[test]
fn prop_reset_restores_fabric_exactly() {
    let mut rng = Rng::new(0x5EED_10);
    for case in 0..12u64 {
        let mut cfg = small();
        cfg.seed = rng.next_u64();
        cfg.system.fpgas_per_wafer = *rng.choose(&[2usize, 4]);
        cfg.workload.sources_per_fpga = *rng.choose(&[8usize, 16, 24]);
        cfg.workload.rate_hz = *rng.choose(&[1e6, 4e6, 8e6]);
        cfg.workload.fan_out = *rng.choose(&[1usize, 2]);
        cfg.workload.zipf_s = *rng.choose(&[0.0, 0.9]);
        cfg.workload.duration = Time::from_us(200);
        let warm_a = run_json("traffic", &cfg);
        let warm_b = run_json("traffic", &cfg);
        let mut cold_cfg = cfg.clone();
        cold_cfg.reuse = ReuseMode::Off;
        let cold = run_json("traffic", &cold_cfg);
        assert_eq!(warm_a, warm_b, "case {case}: warm rerun diverged");
        assert_eq!(warm_a, cold, "case {case}: reuse diverged from cold rebuild");
    }
}

/// Arena handles are positional and rows never move: every handle reads
/// back exactly the bytes last written through it, no matter how many
/// later allocations (or reads through other handles) happen.
#[test]
fn prop_arena_handles_are_stable() {
    let mut rng = Rng::new(0xA7E9A);
    for _case in 0..40u64 {
        let mut f32s = F32Arena::new();
        let mut u64s: Arena<u64> = Arena::new();
        let mut f32_expect: Vec<(bss_extoll::sim::F32Handle, Vec<f32>)> = Vec::new();
        let mut u64_expect: Vec<(bss_extoll::sim::Handle<u64>, u64)> = Vec::new();
        for _ in 0..200 {
            match rng.below(4) {
                0 => {
                    // fresh f32 row, filled through alloc_with
                    let len = rng.range(1, 64) as usize;
                    let seed = rng.next_u64();
                    let h = f32s.alloc_with(len, |row| {
                        let mut r = Rng::new(seed);
                        for v in row.iter_mut() {
                            *v = r.f64() as f32;
                        }
                    });
                    f32_expect.push((h, f32s.row(h).to_vec()));
                }
                1 => {
                    // overwrite an existing row through its handle
                    if let Some(i) = pick(&mut rng, f32_expect.len()) {
                        let (h, expect) = &mut f32_expect[i];
                        for (j, v) in f32s.row_mut(*h).iter_mut().enumerate() {
                            *v += j as f32;
                            expect[j] = *v;
                        }
                    }
                }
                2 => {
                    let val = rng.next_u64();
                    let h = u64s.push(val);
                    u64_expect.push((h, val));
                }
                _ => {
                    if let Some(i) = pick(&mut rng, u64_expect.len()) {
                        let (h, expect) = &mut u64_expect[i];
                        *u64s.get_mut(*h) += 1;
                        *expect += 1;
                    }
                }
            }
        }
        for (h, expect) in &f32_expect {
            assert_eq!(f32s.row(*h), &expect[..], "f32 row moved or was clobbered");
        }
        for (h, expect) in &u64_expect {
            assert_eq!(u64s.get(*h), expect, "u64 row moved or was clobbered");
        }
        // byte accounting covers at least the live payload
        assert!(f32s.resident_bytes() >= (f32s.len() * std::mem::size_of::<f32>()) as u64);
        assert!(u64s.resident_bytes() >= (u64s.len() * std::mem::size_of::<u64>()) as u64);
    }
}

fn pick(rng: &mut Rng, len: usize) -> Option<usize> {
    (len > 0).then(|| rng.below(len as u64) as usize)
}
