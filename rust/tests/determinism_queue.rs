//! Cross-backend, cross-job-count and cross-domain-count determinism.
//!
//! The calendar-wheel event queue (`QueueKind::Wheel`), the parallel
//! sweep runner (`--jobs N`) and the partitioned conservative PDES
//! (`domains=N`) are performance features only: they must be
//! observationally identical to the reference heap backend, the serial
//! runner and the single-domain event loop. These tests pin that
//! contract at the artifact level — byte-identical report JSON and sweep
//! CSV (the determinism bar set in PR 2, extended to PDES in PR 3; see
//! docs/ARCHITECTURE.md for why the merge-key design makes this hold).

use bss_extoll::coordinator::scenario::find;
use bss_extoll::coordinator::sweep::SweepRunner;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::sim::{QueueKind, Time};
use bss_extoll::util::report::Report;
use bss_extoll::wafer::system::SystemConfig;

fn small() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 2,
        torus: TorusSpec::new(2, 2, 1),
        fpgas_per_wafer: 4,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.workload.rate_hz = 4e6;
    cfg.workload.sources_per_fpga = 16;
    cfg.workload.duration = Time::from_us(400);
    cfg
}

/// Run `scenario` on the given backend; returns the pretty report JSON.
fn report_json(scenario: &str, kind: QueueKind) -> String {
    let mut cfg = small();
    cfg.queue = kind;
    find(scenario)
        .unwrap_or_else(|| panic!("scenario {scenario} not registered"))
        .run(&cfg)
        .unwrap_or_else(|e| panic!("{scenario} run failed: {e:#}"))
        .to_json()
        .pretty()
}

#[test]
fn traffic_report_identical_across_backends() {
    let heap = report_json("traffic", QueueKind::Heap);
    let wheel = report_json("traffic", QueueKind::Wheel);
    assert!(heap.contains("rx_events"));
    assert_eq!(heap, wheel);
}

#[test]
fn burst_report_identical_across_backends() {
    assert_eq!(
        report_json("burst", QueueKind::Heap),
        report_json("burst", QueueKind::Wheel)
    );
}

#[test]
fn hotspot_report_identical_across_backends() {
    assert_eq!(
        report_json("hotspot", QueueKind::Heap),
        report_json("hotspot", QueueKind::Wheel)
    );
}

/// The microcircuit report carries two wall-clock metrics
/// (`pjrt_seconds`, `des_seconds`) that can never be byte-identical
/// across runs; every simulated metric must be.
fn canonical_without_wallclock(r: &Report) -> String {
    let mut s = String::new();
    for e in r.entries() {
        if e.key == "pjrt_seconds" || e.key == "des_seconds" {
            continue;
        }
        s.push_str(&format!("{}|{:?}|{}\n", e.key, e.value, e.unit));
    }
    s
}

#[test]
fn microcircuit_report_identical_across_backends() {
    if !bss_extoll::runtime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let run = |kind: QueueKind| {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 2,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.neuro.steps = 15;
        cfg.queue = kind;
        let report = find("microcircuit").unwrap().run(&cfg).unwrap();
        canonical_without_wallclock(&report)
    };
    let heap = run(QueueKind::Heap);
    assert!(heap.contains("spikes_total"));
    assert_eq!(heap, run(QueueKind::Wheel));
}

#[test]
fn sweep_csv_identical_across_backends() {
    let scenario = find("traffic").unwrap();
    let grid = "rate_hz=1e6,4e6;fan_out=1,2";
    let run = |kind: QueueKind| {
        let mut base = small();
        base.queue = kind;
        SweepRunner::from_grid(base, grid)
            .unwrap()
            .run(scenario.as_ref())
            .unwrap()
            .to_csv()
    };
    let heap = run(QueueKind::Heap);
    assert_eq!(heap.lines().count(), 5, "header + 4 points");
    assert_eq!(heap, run(QueueKind::Wheel));
}

/// Run `scenario` partitioned into `domains` PDES domains; pretty JSON.
fn report_json_domains(scenario: &str, domains: usize) -> String {
    let mut cfg = small();
    cfg.domains = domains;
    find(scenario)
        .unwrap_or_else(|| panic!("scenario {scenario} not registered"))
        .run(&cfg)
        .unwrap_or_else(|e| panic!("{scenario} domains={domains} run failed: {e:#}"))
        .to_json()
        .pretty()
}

#[test]
fn traffic_report_identical_across_domain_counts() {
    let serial = report_json_domains("traffic", 1);
    assert!(serial.contains("rx_events"));
    for d in [2usize, 4] {
        assert_eq!(serial, report_json_domains("traffic", d), "domains={d}");
    }
}

#[test]
fn burst_report_identical_across_domain_counts() {
    let serial = report_json_domains("burst", 1);
    for d in [2usize, 4] {
        assert_eq!(serial, report_json_domains("burst", d), "domains={d}");
    }
}

#[test]
fn hotspot_report_identical_across_domain_counts() {
    let serial = report_json_domains("hotspot", 1);
    for d in [2usize, 4] {
        assert_eq!(serial, report_json_domains("hotspot", d), "domains={d}");
    }
}

/// Domains and queue backend compose: heap × 4 domains must equal
/// wheel × 1 domain.
#[test]
fn domains_and_queue_backend_compose() {
    let mut a = small();
    a.queue = QueueKind::Heap;
    a.domains = 4;
    let mut b = small();
    b.queue = QueueKind::Wheel;
    b.domains = 1;
    let scenario = find("traffic").unwrap();
    assert_eq!(
        scenario.run(&a).unwrap().to_json().pretty(),
        scenario.run(&b).unwrap().to_json().pretty()
    );
}

#[test]
fn sweep_csv_identical_across_domain_counts() {
    let scenario = find("traffic").unwrap();
    let grid = "rate_hz=1e6,4e6;fan_out=1,2";
    let run = |domains: usize| {
        let mut base = small();
        base.domains = domains;
        SweepRunner::from_grid(base, grid)
            .unwrap()
            .run(scenario.as_ref())
            .unwrap()
            .to_csv()
    };
    let serial = run(1);
    assert_eq!(serial.lines().count(), 5, "header + 4 points");
    for d in [2usize, 4] {
        assert_eq!(serial, run(d), "sweep CSV diverged at domains={d}");
    }
}

#[test]
fn sweep_jobs4_artifacts_identical_to_serial() {
    let scenario = find("traffic").unwrap();
    let grid = "eviction=most_urgent,fullest,oldest,round_robin;fan_out=1,2";
    let serial = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .run(scenario.as_ref())
        .unwrap();
    let parallel = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .jobs(4)
        .run(scenario.as_ref())
        .unwrap();
    assert_eq!(serial.points.len(), 8);
    assert_eq!(serial.to_csv(), parallel.to_csv());
    assert_eq!(
        serial.to_json().pretty(),
        parallel.to_json().pretty()
    );
}
