//! Cross-backend, cross-job-count, cross-domain-count and cross-cache
//! determinism.
//!
//! The calendar-wheel event queue (`QueueKind::Wheel`), the parallel
//! sweep runner (`--jobs N`), the partitioned conservative PDES
//! (`domains=N`, `sync=window|channel|free`), the sweep-level resource cache
//! (PR 4), packet-payload pooling (PR 4), the fault-injection
//! subsystem's seed-derived randomness (PR 6) and the link-level
//! reliability protocol's retransmission timers (PR 7) are performance
//! features (or, for faults/reliability, deterministic physics) on top
//! of the reference:
//! they must be observationally identical to the reference heap
//! backend, the serial runner, the single-domain event loop, the
//! windowed synchronization protocol, a cold per-point prepare and
//! unpooled allocation. These tests pin that contract at the artifact
//! level — byte-identical report JSON and sweep CSV (the determinism bar
//! set in PR 2, extended in PR 3/PR 4/PR 5; see docs/ARCHITECTURE.md for
//! why the merge-key and cache-key designs make this hold).
//!
//! Since PR 8 the cross-sync-mode gates are thin callers into the
//! shared [`support::DiffMatrix`] driver; the full differential matrix
//! (every mode × domain count × backend × fault × reliability) lives in
//! `rust/tests/differential_sync.rs`.

#[path = "support/mod.rs"]
mod support;

use bss_extoll::coordinator::scenario::find;
use bss_extoll::coordinator::sweep::SweepRunner;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::packet::pool;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::sim::{QueueKind, SyncMode};
use bss_extoll::util::report::Report;
use bss_extoll::wafer::system::SystemConfig;
use support::{small, DiffMatrix};

/// Run `scenario` on the given backend; returns the pretty report JSON.
fn report_json(scenario: &str, kind: QueueKind) -> String {
    let mut cfg = small();
    cfg.queue = kind;
    find(scenario)
        .unwrap_or_else(|| panic!("scenario {scenario} not registered"))
        .run(&cfg)
        .unwrap_or_else(|e| panic!("{scenario} run failed: {e:#}"))
        .to_json()
        .pretty()
}

#[test]
fn traffic_report_identical_across_backends() {
    let heap = report_json("traffic", QueueKind::Heap);
    let wheel = report_json("traffic", QueueKind::Wheel);
    assert!(heap.contains("rx_events"));
    assert_eq!(heap, wheel);
}

#[test]
fn burst_report_identical_across_backends() {
    assert_eq!(
        report_json("burst", QueueKind::Heap),
        report_json("burst", QueueKind::Wheel)
    );
}

#[test]
fn hotspot_report_identical_across_backends() {
    assert_eq!(
        report_json("hotspot", QueueKind::Heap),
        report_json("hotspot", QueueKind::Wheel)
    );
}

/// The microcircuit report carries two wall-clock metrics
/// (`pjrt_seconds`, `des_seconds`) that can never be byte-identical
/// across runs; every simulated metric must be.
fn canonical_without_wallclock(r: &Report) -> String {
    let mut s = String::new();
    for e in r.entries() {
        if e.key == "pjrt_seconds" || e.key == "des_seconds" {
            continue;
        }
        s.push_str(&format!("{}|{:?}|{}\n", e.key, e.value, e.unit));
    }
    s
}

#[test]
fn microcircuit_report_identical_across_backends() {
    if !bss_extoll::runtime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let run = |kind: QueueKind| {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 2,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.neuro.steps = 15;
        cfg.queue = kind;
        let report = find("microcircuit").unwrap().run(&cfg).unwrap();
        canonical_without_wallclock(&report)
    };
    let heap = run(QueueKind::Heap);
    assert!(heap.contains("spikes_total"));
    assert_eq!(heap, run(QueueKind::Wheel));
}

#[test]
fn sweep_csv_identical_across_backends() {
    let scenario = find("traffic").unwrap();
    let grid = "rate_hz=1e6,4e6;fan_out=1,2";
    let run = |kind: QueueKind| {
        let mut base = small();
        base.queue = kind;
        SweepRunner::from_grid(base, grid)
            .unwrap()
            .run(scenario)
            .unwrap()
            .to_csv()
    };
    let heap = run(QueueKind::Heap);
    assert_eq!(heap.lines().count(), 5, "header + 4 points");
    assert_eq!(heap, run(QueueKind::Wheel));
}

/// Run `scenario` partitioned into `domains` PDES domains; pretty JSON.
fn report_json_domains(scenario: &str, domains: usize) -> String {
    let mut cfg = small();
    cfg.domains = domains;
    find(scenario)
        .unwrap_or_else(|| panic!("scenario {scenario} not registered"))
        .run(&cfg)
        .unwrap_or_else(|e| panic!("{scenario} domains={domains} run failed: {e:#}"))
        .to_json()
        .pretty()
}

#[test]
fn traffic_report_identical_across_domain_counts() {
    let serial = report_json_domains("traffic", 1);
    assert!(serial.contains("rx_events"));
    for d in [2usize, 4] {
        assert_eq!(serial, report_json_domains("traffic", d), "domains={d}");
    }
}

#[test]
fn burst_report_identical_across_domain_counts() {
    let serial = report_json_domains("burst", 1);
    for d in [2usize, 4] {
        assert_eq!(serial, report_json_domains("burst", d), "domains={d}");
    }
}

#[test]
fn hotspot_report_identical_across_domain_counts() {
    let serial = report_json_domains("hotspot", 1);
    for d in [2usize, 4] {
        assert_eq!(serial, report_json_domains("hotspot", d), "domains={d}");
    }
}

/// The PR 5 acceptance gate, now a thin caller into the differential
/// harness (`rust/tests/differential_sync.rs` runs the wider matrix):
/// reports byte-identical across every sync mode × domains=1/2/4.
#[test]
fn traffic_report_identical_across_sync_modes_and_domain_counts() {
    let serial = DiffMatrix::new("traffic", small()).assert_identical();
    assert!(serial.contains("rx_events"));
}

#[test]
fn burst_and_hotspot_reports_identical_across_sync_modes() {
    for scenario in ["burst", "hotspot"] {
        DiffMatrix::new(scenario, small()).domains(&[1, 4]).assert_identical();
    }
}

/// Sync protocol and queue backend compose: every mode on the heap
/// backend must equal the serial wheel run (thin caller — the serial
/// reference cell runs on the first configured backend, so pinning
/// wheel first and sweeping heap crosses the two axes).
#[test]
fn sync_modes_and_queue_backends_compose() {
    DiffMatrix::new("traffic", small())
        .kinds(&[QueueKind::Wheel, QueueKind::Heap])
        .domains(&[2, 4])
        .assert_identical();
}

/// Domains and queue backend compose: heap × 4 domains must equal
/// wheel × 1 domain.
#[test]
fn domains_and_queue_backend_compose() {
    let mut a = small();
    a.queue = QueueKind::Heap;
    a.domains = 4;
    let mut b = small();
    b.queue = QueueKind::Wheel;
    b.domains = 1;
    let scenario = find("traffic").unwrap();
    assert_eq!(
        scenario.run(&a).unwrap().to_json().pretty(),
        scenario.run(&b).unwrap().to_json().pretty()
    );
}

#[test]
fn sweep_csv_identical_across_domain_counts() {
    let scenario = find("traffic").unwrap();
    let grid = "rate_hz=1e6,4e6;fan_out=1,2";
    let run = |domains: usize| {
        let mut base = small();
        base.domains = domains;
        SweepRunner::from_grid(base, grid)
            .unwrap()
            .run(scenario)
            .unwrap()
            .to_csv()
    };
    let serial = run(1);
    assert_eq!(serial.lines().count(), 5, "header + 4 points");
    for d in [2usize, 4] {
        assert_eq!(serial, run(d), "sweep CSV diverged at domains={d}");
    }
}

#[test]
fn sweep_jobs4_artifacts_identical_to_serial() {
    let scenario = find("traffic").unwrap();
    let grid = "eviction=most_urgent,fullest,oldest,round_robin;fan_out=1,2";
    let serial = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .run(scenario)
        .unwrap();
    let parallel = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .jobs(4)
        .run(scenario)
        .unwrap();
    assert_eq!(serial.points.len(), 8);
    assert_eq!(serial.to_csv(), parallel.to_csv());
    // full artifact identity includes the surfaced cache counters: the
    // per-key latch makes hit/miss deterministic across job counts
    // (fan_out is the only plan input among the axes → 2 misses, 6 hits)
    assert_eq!(serial.cache.misses, 2);
    assert_eq!(serial.cache.hits, 6);
    assert_eq!(
        serial.to_json().pretty(),
        parallel.to_json().pretty()
    );
}

// ---- PR 4: sweep resource cache + packet pooling -------------------------

/// Cold vs warm cache: re-running a sweep on the same runner serves every
/// point from cached plans; points and CSV stay byte-identical.
#[test]
fn sweep_cache_cold_vs_warm_byte_identical() {
    let scenario = find("traffic").unwrap();
    let grid = "rate_hz=1e6,2e6,4e6;eviction=most_urgent,fullest";
    let runner = SweepRunner::from_grid(small(), grid).unwrap();
    let cold = runner.run(scenario).unwrap();
    // neither axis feeds the route plan: one prepare, five reuses
    assert_eq!(cold.points.len(), 6);
    assert_eq!(cold.cache.misses, 1);
    assert_eq!(cold.cache.hits, 5);
    let warm = runner.run(scenario).unwrap();
    assert_eq!(warm.cache.misses, 0);
    assert_eq!(warm.cache.hits, 6);
    assert_eq!(cold.to_csv(), warm.to_csv());
    // point data identical (the top-level cache counters legitimately
    // differ between a cold and a warm run)
    assert_eq!(
        cold.to_json().get("points").unwrap().to_string(),
        warm.to_json().get("points").unwrap().to_string()
    );
}

/// The cached sweep is byte-identical to per-point `run()` (the
/// pre-redesign serial behaviour: every point prepares from scratch).
#[test]
fn sweep_cache_matches_uncached_per_point_runs() {
    use bss_extoll::coordinator::sweep::apply_override;
    let scenario = find("traffic").unwrap();
    let runner = SweepRunner::new(small()).axis("rate_hz", &["1e6", "4e6"]);
    let cached = runner.run(scenario).unwrap();
    for point in &cached.points {
        let mut cfg = small();
        for (k, v) in &point.params {
            apply_override(&mut cfg, k, v).unwrap();
        }
        let cold = scenario.run(&cfg).unwrap();
        assert_eq!(
            cold.to_json().pretty(),
            point.report.to_json().pretty(),
            "cached sweep point diverged from a cold run at {:?}",
            point.params
        );
    }
}

/// Cache counters — and therefore the whole aggregate JSON — are
/// identical at `--jobs 1/2/4`, even when all points share one key and
/// the workers race for it.
#[test]
fn sweep_cache_counters_identical_across_jobs() {
    let scenario = find("traffic").unwrap();
    let grid = "rate_hz=1e6,2e6,3e6,4e6";
    let serial = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .run(scenario)
        .unwrap();
    assert_eq!(serial.cache.misses, 1);
    assert_eq!(serial.cache.hits, 3);
    for jobs in [2usize, 4] {
        let parallel = SweepRunner::from_grid(small(), grid)
            .unwrap()
            .jobs(jobs)
            .run(scenario)
            .unwrap();
        assert_eq!(
            serial.to_json().pretty(),
            parallel.to_json().pretty(),
            "sweep artifact diverged at jobs={jobs}"
        );
    }
}

/// The acceptance gate: a microcircuit sweep over ≥4 points loads its
/// artifact exactly once (one cache miss), and the sweep's simulated
/// metrics are identical at `--jobs 1/2/4` and equal to cold per-point
/// runs. (Wall-clock metrics are stripped, as for every microcircuit
/// determinism gate.)
#[test]
fn microcircuit_sweep_loads_artifact_once_and_matches_serial() {
    if !bss_extoll::runtime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let scenario = find("microcircuit").unwrap();
    let base = scenario.default_config();
    let grid = "steps=4,6,8,10";
    let canon = |result: &bss_extoll::coordinator::SweepResult| -> String {
        result
            .points
            .iter()
            .map(|p| format!("{:?}|{}", p.params, canonical_without_wallclock(&p.report)))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let serial = SweepRunner::from_grid(base.clone(), grid)
        .unwrap()
        .run(scenario)
        .unwrap();
    assert_eq!(serial.points.len(), 4);
    assert_eq!(
        serial.cache.misses, 1,
        "artifact + weights must be prepared exactly once across the sweep"
    );
    assert_eq!(serial.cache.hits, 3);
    let serial_canon = canon(&serial);
    for jobs in [2usize, 4] {
        let parallel = SweepRunner::from_grid(base.clone(), grid)
            .unwrap()
            .jobs(jobs)
            .run(scenario)
            .unwrap();
        assert_eq!(parallel.cache.misses, 1, "jobs={jobs}");
        assert_eq!(
            serial_canon,
            canon(&parallel),
            "microcircuit sweep diverged at jobs={jobs}"
        );
    }
    // cold per-point runs (pre-redesign behaviour) agree too
    use bss_extoll::coordinator::sweep::apply_override;
    for point in &serial.points {
        let mut cfg = base.clone();
        for (k, v) in &point.params {
            apply_override(&mut cfg, k, v).unwrap();
        }
        let cold = scenario.run(&cfg).unwrap();
        assert_eq!(
            canonical_without_wallclock(&cold),
            canonical_without_wallclock(&point.report),
            "cached microcircuit point diverged at {:?}",
            point.params
        );
    }
}

// ---- PR 6: fault injection -----------------------------------------------

/// Run `scenario` with a fault spec, an explicit sync protocol and a
/// domain count; pretty JSON.
fn report_json_fault(scenario: &str, spec: &str, sync: SyncMode, domains: usize) -> String {
    let mut cfg = small();
    cfg.fault = bss_extoll::fault::FaultConfig::parse_spec(spec)
        .unwrap_or_else(|e| panic!("fault spec {spec:?}: {e}"));
    cfg.sync = sync;
    cfg.domains = domains;
    find(scenario)
        .unwrap_or_else(|| panic!("scenario {scenario} not registered"))
        .run(&cfg)
        .unwrap_or_else(|e| {
            panic!(
                "{scenario} fault={spec} sync={} domains={domains} failed: {e:#}",
                sync.as_str()
            )
        })
        .to_json()
        .pretty()
}

/// The PR 6 acceptance gate, now a thin caller into the differential
/// harness: a faulted fabric is still deterministic — reports are
/// byte-identical across every sync mode × domains=1/2/4 for a spec
/// exercising every fault mechanism (cable failures with re-routing,
/// packet loss, serialization degradation and latency jitter; all
/// randomness is seed-derived per NIC, and the merge-key contract makes
/// per-NIC draw order partition-independent).
#[test]
fn fault_sweep_report_identical_across_sync_modes_and_domain_counts() {
    let spec = "fail:0.1|loss:0.02|degrade:0.2|degrade_factor:2.0|jitter_ns:30";
    let mut cfg = small();
    cfg.fault = bss_extoll::fault::FaultConfig::parse_spec(spec).unwrap();
    let serial = DiffMatrix::new("fault_sweep", cfg).label("fault ").assert_identical();
    assert!(serial.contains("deliverability"));
}

/// Histogram metrics survive the partitioning too: `latency_dist` under
/// jitter is byte-identical across domain counts.
#[test]
fn latency_dist_report_identical_across_domain_counts() {
    let spec = "jitter_ns:40";
    let serial = report_json_fault("latency_dist", spec, SyncMode::Channel, 1);
    assert!(serial.contains("latency_hist"));
    for d in [2usize, 4] {
        assert_eq!(
            serial,
            report_json_fault("latency_dist", spec, SyncMode::Channel, d),
            "latency_dist domains={d}"
        );
    }
}

/// A fault axis sweeps cleanly: the compact '|' spec survives the
/// ','-split grid grammar, all points share one cached plan (the fault
/// model is built at execute time), and `--jobs 4` artifacts are
/// byte-identical to serial.
#[test]
fn fault_axis_sweep_identical_across_jobs() {
    let scenario = find("fault_sweep").unwrap();
    let grid = "fault=none,fail:0.05,fail:0.1|loss:0.01";
    let serial = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .run(scenario)
        .unwrap();
    assert_eq!(serial.points.len(), 3);
    assert_eq!(serial.cache.misses, 1, "fault points must share one plan");
    assert_eq!(serial.cache.hits, 2);
    let parallel = SweepRunner::from_grid(small(), grid)
        .unwrap()
        .jobs(4)
        .run(scenario)
        .unwrap();
    assert_eq!(serial.to_csv(), parallel.to_csv());
    assert_eq!(serial.to_json().pretty(), parallel.to_json().pretty());
}

// ---- PR 7: link-level reliability ----------------------------------------

/// The PR 7 acceptance gate, now a thin caller into the differential
/// harness: retransmission timers, ACK/NACK control frames and replay
/// are ordinary intra-node events under the merge-key contract — with
/// the reliability layer recovering packets on a fabric exercising
/// every fault mechanism, reports stay byte-identical across every
/// sync mode × domains=1/2/4 × heap/wheel.
#[test]
fn reliability_report_identical_across_sync_domains_and_backends() {
    let spec = "fail:0.1|loss:0.02|degrade:0.2|degrade_factor:2.0|jitter_ns:30";
    let mut cfg = small();
    cfg.system.nic.reliability = bss_extoll::extoll::link::Reliability::Link;
    cfg.fault = bss_extoll::fault::FaultConfig::parse_spec(spec).unwrap();
    let serial = DiffMatrix::new("reliability_sweep", cfg)
        .label("reliability=link ")
        .kinds(&[QueueKind::Heap, QueueKind::Wheel])
        .assert_identical();
    assert!(serial.contains("recovered_events"));
    assert!(serial.contains("retransmissions"));
}

/// The layer is opt-in: with `reliability=off` (the default) the faulted
/// fabric reproduces today's fault_sweep report byte-identically — the
/// knob's existence changes nothing.
#[test]
fn reliability_off_reproduces_the_fault_sweep_bytes() {
    let spec = "fail:0.1|loss:0.02|jitter_ns:30";
    let baseline = report_json_fault("fault_sweep", spec, SyncMode::Channel, 2);
    let mut cfg = small();
    cfg.system.nic.reliability = bss_extoll::extoll::link::Reliability::Off;
    cfg.fault = bss_extoll::fault::FaultConfig::parse_spec(spec).unwrap();
    cfg.sync = SyncMode::Channel;
    cfg.domains = 2;
    let explicit_off = find("fault_sweep").unwrap().run(&cfg).unwrap().to_json().pretty();
    assert_eq!(baseline, explicit_off);
}

/// A `reliability=off,link` axis sweeps cleanly: the layer is
/// execute-time state so all points share one cached plan, and `--jobs 4`
/// artifacts are byte-identical to serial.
#[test]
fn reliability_axis_sweep_identical_across_jobs() {
    let scenario = find("reliability_sweep").unwrap();
    let mut base = small();
    base.fault = bss_extoll::fault::FaultConfig::parse_spec("loss:0.02").unwrap();
    let grid = "reliability=off,link;retx_timeout_ns=1000,2000";
    let serial = SweepRunner::from_grid(base.clone(), grid)
        .unwrap()
        .run(scenario)
        .unwrap();
    assert_eq!(serial.points.len(), 4);
    assert_eq!(serial.cache.misses, 1, "reliability points must share one plan");
    assert_eq!(serial.cache.hits, 3);
    let parallel = SweepRunner::from_grid(base, grid)
        .unwrap()
        .jobs(4)
        .run(scenario)
        .unwrap();
    assert_eq!(serial.to_csv(), parallel.to_csv());
    assert_eq!(serial.to_json().pretty(), parallel.to_json().pretty());
}

/// Packet-payload pooling is a perf knob only: reports are byte-identical
/// with the pool disabled.
#[test]
fn packet_pool_does_not_change_physics() {
    let scenario = find("traffic").unwrap();
    let mut cfg = small();
    cfg.workload.fan_out = 2;
    pool::set_enabled(false);
    let unpooled = scenario.run(&cfg).unwrap().to_json().pretty();
    pool::set_enabled(true);
    let pooled = scenario.run(&cfg).unwrap().to_json().pretty();
    assert_eq!(unpooled, pooled, "packet pooling changed observable results");
}
