//! Property-based tests (hand-rolled generators on the deterministic RNG):
//! random operation sequences against the protocol invariants the paper's
//! hardware must uphold — no loss, no duplication, credit conservation,
//! bounded buffers, wrapped-timestamp coherence.

use bss_extoll::extoll::rma::Notification;
use bss_extoll::extoll::routing::{links_on_route, route};
use bss_extoll::extoll::torus::{NodeAddr, TorusSpec};
use bss_extoll::fpga::bucket::BucketConfig;
use bss_extoll::fpga::event::{ts_before_eq, RoutedEvent};
use bss_extoll::fpga::lookup::EndpointAddr;
use bss_extoll::fpga::manager::{BucketManager, EvictionPolicy, ManagerConfig};
use bss_extoll::host::ringbuf::{RingConsumer, RingProducer};
use bss_extoll::sim::Time;
use bss_extoll::util::json::Json;
use bss_extoll::util::rng::Rng;

const CASES: u64 = 60;

/// Random manager configurations × random insert/poll/drain interleavings:
/// every accepted event appears in exactly one flush batch.
#[test]
fn prop_manager_conserves_events() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xABCD + case);
        let cfg = ManagerConfig {
            n_buckets: rng.range(1, 24) as usize,
            bucket: BucketConfig {
                capacity: rng.range(1, 124) as usize,
                deadline_margin: rng.range(10, 2000) as u16,
                concurrent: rng.chance(0.7),
            },
            eviction: *rng.choose(&[
                EvictionPolicy::MostUrgent,
                EvictionPolicy::Fullest,
                EvictionPolicy::Oldest,
                EvictionPolicy::RoundRobin,
            ]),
        };
        let mut mgr = BucketManager::new(cfg);
        let n_dests = rng.range(1, 200) as u16;
        let mut accepted = 0u64;
        let mut flushed = 0u64;
        let mut draining: Vec<usize> = Vec::new();
        let mut now: u16 = 0;
        for _ in 0..2000 {
            match rng.below(10) {
                0..=5 => {
                    now = (now + rng.below(4) as u16) & 0x7FFF;
                    let dest = EndpointAddr::new(NodeAddr(rng.below(n_dests as u64) as u16), 0);
                    let deadline = (now as u32 + rng.range(1, 3000) as u32) as u16 & 0x7FFF;
                    let r = mgr.insert(dest, RoutedEvent::new(1, deadline, Time::ZERO));
                    if r.accepted {
                        accepted += 1;
                    }
                    for b in r.batches {
                        flushed += b.events.len() as u64;
                        draining.push(b.bucket_idx);
                    }
                }
                6..=7 => {
                    for b in mgr.poll_deadlines(now) {
                        flushed += b.events.len() as u64;
                        draining.push(b.bucket_idx);
                    }
                }
                _ => {
                    if !draining.is_empty() {
                        let i = rng.index(draining.len());
                        let idx = draining.swap_remove(i);
                        mgr.drain_complete(idx);
                    }
                }
            }
            // invariant: buffered + flushed == accepted at all times
            assert_eq!(
                mgr.buffered_events() as u64 + flushed,
                accepted,
                "case {case}: conservation violated mid-run"
            );
        }
        // settle: complete outstanding drains, then flush until dry (a
        // draining bucket cannot cut a second batch until its packet left)
        for idx in draining.drain(..) {
            mgr.drain_complete(idx);
        }
        loop {
            let batches = mgr.flush_all();
            if batches.is_empty() {
                break;
            }
            for b in batches {
                flushed += b.events.len() as u64;
                mgr.drain_complete(b.bucket_idx);
            }
        }
        assert_eq!(mgr.buffered_events(), 0, "case {case}: events stranded");
        assert_eq!(flushed, accepted, "case {case}: final conservation violated");
    }
}

/// Ring-buffer protocol: random produce/notify/consume/credit interleaving
/// never overruns and conserves every byte.
#[test]
fn prop_ringbuffer_conservation() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xBEEF + case);
        let size = 1u64 << rng.range(8, 16);
        let mut p = RingProducer::new(0, size);
        let mut c = RingConsumer::new(size);
        let mut notified_pending = 0u64; // written, notification not yet seen
        for _ in 0..3000 {
            match rng.below(4) {
                0 => {
                    let n = rng.range(1, size / 2);
                    if p.write(n).is_some() {
                        notified_pending += n;
                    }
                }
                1 => {
                    if notified_pending > 0 {
                        let n = rng.range(1, notified_pending);
                        c.notify_written(n);
                        notified_pending -= n;
                    }
                }
                2 => {
                    let freed = c.consume(rng.range(1, size));
                    if freed > 0 {
                        p.credit(freed);
                    }
                }
                _ => {
                    // idle tick: check the conservation invariant
                }
            }
            assert_eq!(
                p.space() + notified_pending + c.available(),
                size,
                "case {case}: ring accounting broken"
            );
            assert!(p.bytes_written >= c.bytes_consumed);
        }
    }
}

/// Routing: for random torus shapes and random pairs, routes are minimal,
/// dimension-ordered, and consistent with links_on_route.
#[test]
fn prop_routing_minimal_and_ordered() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x2222 + case);
        let t = TorusSpec::new(
            rng.range(1, 8) as u16,
            rng.range(1, 8) as u16,
            rng.range(1, 8) as u16,
        );
        for _ in 0..50 {
            let a = NodeAddr(rng.below(t.n_nodes() as u64) as u16);
            let b = NodeAddr(rng.below(t.n_nodes() as u64) as u16);
            let path = route(&t, a, b);
            assert_eq!(path.len() as u32, t.hop_distance(a, b));
            let mut axis = 0;
            let mut here = a;
            for d in &path {
                assert!(d.axis() >= axis, "not dimension-ordered");
                axis = d.axis();
                here = t.neighbor(here, *d);
            }
            assert_eq!(here, b);
            assert_eq!(links_on_route(&t, a, b).len(), path.len());
        }
    }
}

/// Property (PR 6, adaptive routing): **the fault-aware router agrees
/// exactly with live-graph reachability.** For any random cable-failure
/// set (both directions of each cable, like `FaultModel`), every
/// `(src, dst)` pair the live graph connects is reached on a loop-free
/// *shortest live* path that avoids every dead link; every pair it does
/// not connect reports `Hop::Unreachable` instead of panicking. This
/// subsumes the "connected fault set ⇒ all destinations reached"
/// guarantee: when the whole live graph stays connected, every pair
/// falls into the first arm.
#[test]
fn prop_adaptive_routing_reaches_every_live_destination() {
    use bss_extoll::extoll::routing::{
        live_distances, next_hop_with, route_with, Hop, LinkStatus,
    };
    use bss_extoll::extoll::torus::{Dir, DIRS};
    use std::collections::BTreeSet;

    struct DeadSet(BTreeSet<(u16, u8)>);
    impl LinkStatus for DeadSet {
        fn alive(&self, from: NodeAddr, dir: Dir) -> bool {
            !self.0.contains(&(from.0, dir.port()))
        }
    }

    for case in 0..CASES {
        let mut rng = Rng::new(0x6666 + case);
        let t = TorusSpec::new(
            rng.range(2, 6) as u16,
            rng.range(1, 6) as u16,
            rng.range(1, 4) as u16,
        );
        let mut dead = BTreeSet::new();
        for _ in 0..rng.below(1 + t.n_nodes() as u64 / 2) {
            let a = NodeAddr(rng.below(t.n_nodes() as u64) as u16);
            let d = DIRS[rng.below(6) as usize];
            let b = t.neighbor(a, d);
            if b == a {
                continue; // size-1 axis self-loop; never a cable
            }
            dead.insert((a.0, d.port()));
            dead.insert((b.0, d.opposite().port()));
        }
        let status = DeadSet(dead);
        for _ in 0..30 {
            let src = NodeAddr(rng.below(t.n_nodes() as u64) as u16);
            let dst = NodeAddr(rng.below(t.n_nodes() as u64) as u16);
            let dist = live_distances(&t, &status, dst);
            match route_with(&t, &status, src, dst) {
                // reachable: shortest in the live graph, dead links
                // avoided, destination reached (the shared walker's loop
                // guard asserts loop-freedom on the way)
                Some(p) => {
                    assert_eq!(p.len() as u32, dist[src.0 as usize], "{src}->{dst}");
                    let mut here = src;
                    for d in &p {
                        assert!(status.alive(here, *d), "route used dead link at {here}");
                        here = t.neighbor(here, *d);
                    }
                    assert_eq!(here, dst);
                }
                None => {
                    assert_eq!(
                        dist[src.0 as usize],
                        u32::MAX,
                        "{src}->{dst} is live-reachable but reported unreachable"
                    );
                    assert_eq!(next_hop_with(&t, &status, src, dst), Hop::Unreachable);
                }
            }
        }
    }
}

/// Wrapped 15-bit timestamps behave like a total order inside any window
/// smaller than half the range.
#[test]
fn prop_timestamp_window_order() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x3333 + case);
        let base = rng.below(1 << 15) as u16;
        let mut offs: Vec<u16> = (0..20).map(|_| rng.below(16000) as u16).collect();
        offs.sort_unstable();
        for w in offs.windows(2) {
            let a = (base.wrapping_add(w[0])) & 0x7FFF;
            let b = (base.wrapping_add(w[1])) & 0x7FFF;
            assert!(
                ts_before_eq(a, b),
                "case {case}: {a:#x} should be ≤ {b:#x} (base {base:#x})"
            );
        }
    }
}

/// Notification codec: random words round-trip (valid kinds) and decode
/// never panics on arbitrary bits.
#[test]
fn prop_notification_codec() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x4444 + case);
        for _ in 0..100 {
            let n = match rng.below(3) {
                0 => Notification::DataWritten {
                    channel: rng.below(1 << 12) as u16,
                    bytes: rng.below(1 << 48),
                },
                1 => Notification::SpaceFreed {
                    channel: rng.below(1 << 12) as u16,
                    bytes: rng.below(1 << 48),
                },
                _ => Notification::Completion {
                    channel: rng.below(1 << 12) as u16,
                    value: rng.below(1 << 48),
                },
            };
            assert_eq!(Notification::decode(n.encode()), Some(n));
            let _ = Notification::decode(rng.next_u64()); // must not panic
        }
    }
}

/// JSON: random values survive emit → parse → emit.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.below(1 << 53) as f64) - (1u64 << 52) as f64),
            3 => {
                let len = rng.below(12) as usize;
                let s: String = (0..len)
                    .map(|_| char::from_u32(rng.range(32, 0x2FA0) as u32).unwrap_or('x'))
                    .collect();
                Json::Str(s)
            }
            4 => {
                let mut a = Json::arr();
                for _ in 0..rng.below(5) {
                    a.push(random_json(rng, depth - 1));
                }
                a
            }
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.insert(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    for case in 0..CASES {
        let mut rng = Rng::new(0x5555 + case);
        let v = random_json(&mut rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
        let pretty = v.pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v, "case {case} (pretty)");
    }
}

/// Random torus shapes × random domain counts: the PDES domain map is a
/// true partition (every node in exactly one domain, near-equal block
/// sizes), its inter-domain edge set is symmetric and complete, and the
/// lookahead `extoll::network::pdes_lookahead` derives equals the true
/// minimum message latency over those edges.
#[test]
fn prop_domain_partition_invariants() {
    use bss_extoll::extoll::network::pdes_lookahead;
    use bss_extoll::extoll::nic::NicConfig;
    use bss_extoll::extoll::torus::{DomainMap, DIRS};

    for case in 0..CASES {
        let mut rng = Rng::new(0xD0_17 + case);
        let spec = TorusSpec::new(
            rng.range(1, 7) as u16,
            rng.range(1, 7) as u16,
            rng.range(1, 5) as u16,
        );
        let requested = rng.range(1, 9) as usize;
        let dm = DomainMap::new(spec, requested);
        let n_domains = dm.n_domains();
        assert!(n_domains >= 1 && n_domains <= spec.n_nodes().min(requested.max(1)));

        // every node lands in exactly one domain; blocks near-equal
        let mut counts = vec![0usize; n_domains];
        for a in spec.nodes() {
            let d = dm.domain_of(a) as usize;
            assert!(d < n_domains, "case {case}: node {a} -> domain {d}");
            counts[d] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), spec.n_nodes(), "case {case}");
        let (min, max) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(min >= 1, "case {case}: empty domain");
        assert!(max - min <= 1, "case {case}: unbalanced {min}..{max}");

        // inter-domain edges: exactly the cross-domain neighbor pairs,
        // and symmetric under direction reversal
        let edges = dm.inter_domain_edges();
        for &(a, d, b) in &edges {
            assert_eq!(spec.neighbor(a, d), b, "case {case}");
            assert_ne!(dm.domain_of(a), dm.domain_of(b), "case {case}");
            assert!(
                edges.contains(&(b, d.opposite(), a)),
                "case {case}: asymmetric edge ({a}, {d:?}, {b})"
            );
        }
        let expected: usize = spec
            .nodes()
            .map(|a| {
                DIRS.iter()
                    .filter(|&&d| dm.domain_of(a) != dm.domain_of(spec.neighbor(a, d)))
                    .count()
            })
            .sum();
        assert_eq!(edges.len(), expected, "case {case}: edge set incomplete");

        // lookahead == true minimum message latency over inter-domain
        // links, derived here independently of min_link_latency's
        // implementation: a credit return pays cable + hop on the reverse
        // link; a packet pays at least one byte of serialization on top
        let nic = NicConfig {
            cable_latency: Time::from_ps(rng.range(100, 20_000)),
            hop_latency: Time::from_ps(rng.range(1_000, 200_000)),
            ..NicConfig::default()
        };
        let lookahead = pdes_lookahead(&dm, &nic);
        if edges.is_empty() {
            assert_eq!(n_domains, 1, "case {case}");
            assert!(lookahead.is_none(), "case {case}");
        } else {
            let credit = nic.cable_latency + nic.hop_latency;
            let min_packet = nic.ser_time(1) + nic.cable_latency + nic.hop_latency;
            let want = credit.min(min_packet);
            let la = lookahead.unwrap_or_else(|| panic!("case {case}: no lookahead"));
            assert_eq!(la, want, "case {case}: lookahead != true min latency");
            assert!(la > Time::ZERO, "case {case}: zero lookahead");
            // the conservative bound must lower-bound BOTH message kinds
            assert!(la <= credit && la <= min_packet, "case {case}");
        }
    }
}

/// Property (PR 5/PR 8, conservative synchronization): **any
/// partitioning under any sync protocol reproduces the serial
/// trajectory.** Random rings of relay actors (random size, random
/// per-edge latencies, random hop budgets, a zero-delay sink per node)
/// are run serially, then partitioned into random contiguous domain
/// blocks under the windowed protocol, per-neighbor channel clocks and
/// the barrier-free protocol (channels built from the actual
/// cross-domain edges) — every sink must record the identical
/// `(time, value)` sequence, and the processed-event counts must match.
#[test]
fn prop_partition_sync_modes_match_serial() {
    use bss_extoll::sim::{Actor, ActorId, ChannelGraph, Ctx, Partition, QueueKind, Sim, SyncMode};

    #[derive(Clone, Debug, PartialEq)]
    enum M {
        Hop(u32),
        Echo(u32),
    }

    /// A ring node: records each Hop at its sink (zero delay, same
    /// domain), then forwards Hop(n-1) to a randomly chosen neighbor
    /// over that edge's latency. The RNG is actor-local state, so the
    /// draw sequence is a function of the per-actor delivery order —
    /// which the engine contract makes partition-independent.
    struct RingNode {
        rng: Rng,
        right: ActorId,
        left: ActorId,
        d_right: Time,
        d_left: Time,
        sink: ActorId,
    }

    impl Actor<M> for RingNode {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Hop(n) = msg {
                ctx.send(self.sink, Time::ZERO, M::Echo(n));
                if n > 0 {
                    let (peer, delay) = if self.rng.chance(0.5) {
                        (self.right, self.d_right)
                    } else {
                        (self.left, self.d_left)
                    };
                    ctx.send(peer, delay, M::Hop(n - 1));
                }
            }
        }
    }

    struct Sink {
        seen: Vec<(Time, u32)>,
    }

    impl Actor<M> for Sink {
        fn handle(&mut self, msg: M, ctx: &mut Ctx<'_, M>) {
            if let M::Echo(n) = msg {
                self.seen.push((ctx.now(), n));
            }
        }
    }

    /// Ring shape drawn per case (latencies in ps, ≥ 1 ns each).
    struct Shape {
        n: usize,
        d_right: Vec<Time>, // edge i -> i+1 (mod n)
        d_left: Vec<Time>,  // edge i -> i-1 (mod n)
        starts: Vec<(Time, usize, u32)>,
    }

    fn draw_shape(rng: &mut Rng) -> Shape {
        let n = rng.range(2, 11) as usize;
        let edge = |rng: &mut Rng| Time::from_ps(rng.range(1_000, 500_000));
        let d_right: Vec<Time> = (0..n).map(|_| edge(rng)).collect();
        let d_left: Vec<Time> = (0..n).map(|_| edge(rng)).collect();
        let starts = (0..rng.range(1, 6) as usize)
            .map(|_| {
                (
                    Time::from_ps(rng.below(100_000)),
                    rng.index(n),
                    rng.range(3, 30) as u32,
                )
            })
            .collect();
        Shape { n, d_right, d_left, starts }
    }

    /// Build the ring; node i = actor 2i, its sink = actor 2i + 1.
    fn build(shape: &Shape, seed: u64, kind: QueueKind) -> Sim<M> {
        let mut sim: Sim<M> = Sim::with_kind(kind);
        let n = shape.n;
        for i in 0..n {
            let node = sim.add(RingNode {
                rng: Rng::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9)),
                right: 2 * ((i + 1) % n),
                left: 2 * ((i + n - 1) % n),
                d_right: shape.d_right[i],
                d_left: shape.d_left[i],
                sink: 2 * i + 1,
            });
            let sink = sim.add(Sink { seen: vec![] });
            assert_eq!((node, sink), (2 * i, 2 * i + 1));
        }
        for &(at, node, hops) in &shape.starts {
            sim.schedule(at, 2 * node, M::Hop(hops));
        }
        sim
    }

    fn sink_trajectories(sim: &Sim<M>, n: usize) -> Vec<Vec<(Time, u32)>> {
        (0..n).map(|i| sim.get::<Sink>(2 * i + 1).seen.clone()).collect()
    }

    const UNTIL: Time = Time::from_ms(100);

    for case in 0..24u64 {
        let mut rng = Rng::new(0x5EC5 + case);
        let shape = draw_shape(&mut rng);
        let seed = rng.next_u64();
        let kind = *rng.choose(&[QueueKind::Heap, QueueKind::Wheel]);

        let mut serial = build(&shape, seed, kind);
        serial.run_until(UNTIL);
        let want = sink_trajectories(&serial, shape.n);
        let want_processed = serial.processed();
        assert!(want.iter().any(|t| !t.is_empty()), "case {case}: no traffic");

        // Rng::range is inclusive: domain counts in 1..=n
        let n_domains = rng.range(1, shape.n as u64) as usize;
        // contiguous blocks: node i (and its sink) -> domain i*D/n
        let dom_of = |i: usize| (i * n_domains / shape.n) as u32;
        let owner: Vec<u32> = (0..2 * shape.n).map(|a| dom_of(a / 2)).collect();

        // the cross-domain edge set of this ring, with true latencies
        let mut edges: Vec<(u32, u32, Time)> = Vec::new();
        let mut lookahead = Time::MAX;
        for i in 0..shape.n {
            let hops = [
                ((i + 1) % shape.n, shape.d_right[i]),
                ((i + shape.n - 1) % shape.n, shape.d_left[i]),
            ];
            for (peer, d) in hops {
                if dom_of(i) != dom_of(peer) {
                    edges.push((dom_of(i), dom_of(peer), d));
                    lookahead = lookahead.min(d);
                }
            }
        }

        for mode in SyncMode::ALL {
            if n_domains == 1 && mode.needs_channel_graph() {
                continue; // single domain has no channels to attach
            }
            let sim = build(&shape, seed, kind);
            let la = if n_domains == 1 { Time::from_ns(1) } else { lookahead };
            let mut part = Partition::split(sim, owner.clone(), n_domains, la);
            if mode.needs_channel_graph() {
                part = part.with_channels(ChannelGraph::from_edges(n_domains, edges.clone()));
            }
            if mode == SyncMode::Free {
                part = part.barrier_free();
            }
            part.run_until(UNTIL);
            assert_eq!(
                part.processed(),
                want_processed,
                "case {case} mode={}",
                mode.as_str()
            );
            let merged = part.into_sim();
            assert_eq!(
                sink_trajectories(&merged, shape.n),
                want,
                "case {case}: trajectory diverged (D={n_domains}, mode={})",
                mode.as_str()
            );
        }
    }
}

/// Property (PR 8, barrier-free stress): **seeded scheduling chaos
/// cannot change a free-mode trajectory.** The free protocol has no
/// rounds, so the OS scheduler chooses how domain advance loops
/// interleave; the conservative closure bounds must absorb every such
/// ordering. Random unidirectional token rings (random size, latencies,
/// token counts and hop budgets) are partitioned into random contiguous
/// blocks and run under `sync=free` with seeded `yield_now` injection
/// (`Partition::with_free_chaos`) perturbing every domain's loop at
/// pseudo-random points — each run must reproduce the serial trajectory
/// and processed count byte-for-byte.
#[test]
fn prop_free_mode_survives_scheduling_chaos() {
    use bss_extoll::sim::{Actor, ActorId, ChannelGraph, Ctx, Partition, QueueKind, Sim};

    #[derive(Clone, Debug)]
    struct Token(u32);

    /// Forwards Token(n-1) to the next ring node; records every visit.
    struct Hop {
        next: ActorId,
        delay: Time,
        seen: Vec<(Time, u32)>,
    }

    impl Actor<Token> for Hop {
        fn handle(&mut self, msg: Token, ctx: &mut Ctx<'_, Token>) {
            self.seen.push((ctx.now(), msg.0));
            if msg.0 > 0 {
                ctx.send(self.next, self.delay, Token(msg.0 - 1));
            }
        }
    }

    for case in 0..12u64 {
        let mut rng = Rng::new(0xF2EE + case);
        let n = rng.range(2, 9) as usize;
        let delays: Vec<Time> =
            (0..n).map(|_| Time::from_ps(rng.range(1_000, 400_000))).collect();
        let starts: Vec<(Time, usize, u32)> = (0..rng.range(1, 5) as usize)
            .map(|_| {
                (Time::from_ps(rng.below(50_000)), rng.index(n), rng.range(5, 60) as u32)
            })
            .collect();
        let kind = *rng.choose(&[QueueKind::Heap, QueueKind::Wheel]);

        let build = |kind: QueueKind| {
            let mut sim: Sim<Token> = Sim::with_kind(kind);
            for i in 0..n {
                sim.add(Hop { next: (i + 1) % n, delay: delays[i], seen: vec![] });
            }
            for &(at, node, hops) in &starts {
                sim.schedule(at, node, Token(hops));
            }
            sim
        };
        let until = Time::from_ms(50);
        let mut serial = build(kind);
        serial.run_until(until);
        let want: Vec<Vec<(Time, u32)>> =
            (0..n).map(|i| serial.get::<Hop>(i).seen.clone()).collect();
        let want_processed = serial.processed();

        let n_domains = rng.range(2, n as u64) as usize;
        let dom_of = |i: usize| (i * n_domains / n) as u32;
        let owner: Vec<u32> = (0..n).map(dom_of).collect();
        let mut edges: Vec<(u32, u32, Time)> = Vec::new();
        let mut lookahead = Time::MAX;
        for i in 0..n {
            let peer = (i + 1) % n;
            if dom_of(i) != dom_of(peer) {
                edges.push((dom_of(i), dom_of(peer), delays[i]));
                lookahead = lookahead.min(delays[i]);
            }
        }

        for _ in 0..3 {
            let chaos_seed = rng.next_u64();
            let mut part = Partition::split(build(kind), owner.clone(), n_domains, lookahead)
                .with_channels(ChannelGraph::from_edges(n_domains, edges.clone()))
                .barrier_free()
                .with_free_chaos(chaos_seed);
            part.run_until(until);
            assert_eq!(
                part.processed(),
                want_processed,
                "case {case} chaos_seed {chaos_seed:#x}: processed count diverged"
            );
            let merged = part.into_sim();
            let got: Vec<Vec<(Time, u32)>> =
                (0..n).map(|i| merged.get::<Hop>(i).seen.clone()).collect();
            assert_eq!(
                got, want,
                "case {case} chaos_seed {chaos_seed:#x}: trajectory diverged \
                 (D={n_domains})"
            );
        }
    }
}

/// Property (PR 7, link reliability): **with a generous retry budget,
/// lossy links deliver every spike event exactly once.** Random coherent
/// system shapes × random loss/degrade/jitter mixes (loss < 1) × random
/// retransmission knobs — including windows small enough to stall fresh
/// traffic and timeouts shorter than the link RTT (spurious replays) —
/// must bring deliverability to exactly 1.0: no residual loss, no
/// give-ups, and no double delivery (deliverability would exceed 1.0 if
/// any event arrived twice, since `delivered_events` counts deliveries).
#[test]
fn prop_link_reliability_delivers_every_event() {
    use bss_extoll::coordinator::scenario::find;
    use bss_extoll::coordinator::ExperimentConfig;
    use bss_extoll::extoll::link::Reliability;
    use bss_extoll::fault::FaultConfig;
    use bss_extoll::sim::QueueKind;
    use bss_extoll::wafer::system::SystemConfig;

    // coherent shapes: torus nodes == n_wafers × concentrators_per_wafer
    // and fpgas_per_wafer divisible by concentrators_per_wafer
    // (n_wafers, torus dims, concentrators_per_wafer, fpgas_per_wafer)
    const SHAPES: &[(usize, (u16, u16, u16), usize, usize)] = &[
        (2, (2, 1, 1), 1, 2),
        (2, (2, 2, 1), 2, 4),
        (4, (2, 2, 1), 1, 2),
        (2, (2, 2, 2), 4, 4),
        (2, (4, 2, 1), 4, 8),
    ];

    let scenario = find("reliability_sweep").expect("registered");
    for case in 0..12u64 {
        let mut rng = Rng::new(0xAC4B + case);
        let &(w, (x, y, z), c, f) = rng.choose(SHAPES);
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: w,
            torus: TorusSpec::new(x, y, z),
            fpgas_per_wafer: f,
            concentrators_per_wafer: c,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 8;
        cfg.workload.fan_out = rng.range(1, 2) as usize;
        cfg.workload.duration = Time::from_us(150);
        cfg.seed = 0xB55 ^ case;
        cfg.queue = *rng.choose(&[QueueKind::Heap, QueueKind::Wheel]);
        cfg.domains = rng.range(1, 2) as usize;
        let degrade = *rng.choose(&[0.0, 0.25]);
        cfg.fault = FaultConfig {
            loss: *rng.choose(&[0.05, 0.1, 0.2, 0.35]),
            degrade,
            degrade_factor: if degrade > 0.0 { 2.0 } else { 1.0 },
            jitter_ns: *rng.choose(&[0.0, 20.0]),
            ..FaultConfig::default()
        };
        cfg.system.nic.reliability = Reliability::Link;
        cfg.system.nic.retx.window = *rng.choose(&[2u32, 8, 32]);
        cfg.system.nic.retx.timeout = Time::from_ns(*rng.choose(&[500u64, 1000, 2000]));
        cfg.system.nic.retx.max_retries = 10_000;
        cfg.system.nic.retx.backoff_cap = rng.below(7) as u32;

        let r = scenario
            .run(&cfg)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        let injected = r.get_count("injected_events").unwrap();
        assert!(injected > 0, "case {case}: no traffic generated");
        assert_eq!(
            r.get_f64("deliverability"),
            Some(1.0),
            "case {case}: loss={} window={} timeout={:?}: not exactly-once",
            cfg.fault.loss,
            cfg.system.nic.retx.window,
            cfg.system.nic.retx.timeout,
        );
        assert_eq!(r.get_count("residual_loss_events"), Some(0), "case {case}");
        assert_eq!(r.get_count("undeliverable_events"), Some(0), "case {case}");
        // the layer demonstrably worked for its keep on a lossy fabric
        assert!(
            r.get_count("retransmissions").unwrap() > 0,
            "case {case}: loss={} produced no retransmissions",
            cfg.fault.loss
        );
    }
}

/// Property (PR 4, cache-key discipline): **CacheKey equality implies
/// Prepared interchangeability.** For random config pairs, whenever a
/// scenario reports equal cache keys, executing one config against the
/// *other* config's prepared resources must be byte-identical to
/// executing it against its own. Random execute-only knobs (rate,
/// duration, eviction, queue, deadline) must never separate keys that
/// share plan inputs; random plan inputs (fan_out, seed, zipf_s) must.
#[test]
fn prop_cache_key_equality_implies_prepared_interchangeable() {
    use bss_extoll::coordinator::scenario::find;
    use bss_extoll::coordinator::ExperimentConfig;
    use bss_extoll::sim::QueueKind;
    use bss_extoll::wafer::system::SystemConfig;

    fn base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.system = SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 4,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        };
        cfg.workload.rate_hz = 2e6;
        cfg.workload.sources_per_fpga = 8;
        cfg.workload.duration = Time::from_us(150);
        cfg
    }

    /// Random mutation: mostly execute-only knobs, sometimes plan inputs.
    fn mutate(cfg: &mut ExperimentConfig, rng: &mut Rng) -> bool {
        let mut touched_plan_input = false;
        for _ in 0..rng.range(1, 4) {
            match rng.below(8) {
                0 => cfg.workload.rate_hz = *rng.choose(&[1e6, 2e6, 4e6]),
                1 => {
                    cfg.workload.duration =
                        Time::from_us(*rng.choose(&[100u64, 150, 200]))
                }
                2 => {
                    cfg.system.manager.eviction = *rng.choose(&[
                        EvictionPolicy::MostUrgent,
                        EvictionPolicy::Fullest,
                    ])
                }
                3 => cfg.queue = *rng.choose(&[QueueKind::Heap, QueueKind::Wheel]),
                4 => cfg.workload.deadline_offset = *rng.choose(&[1500u16, 2000, 2500]),
                5 => cfg.workload.burst_len = *rng.choose(&[32u32, 64]),
                6 => {
                    cfg.workload.fan_out = *rng.choose(&[1usize, 2]);
                    touched_plan_input = true;
                }
                _ => {
                    cfg.seed = 0xB55 ^ rng.below(2);
                    touched_plan_input = true;
                }
            }
        }
        touched_plan_input
    }

    // guaranteed equal-key coverage (execute-only knobs differ), so the
    // property is exercised even if the random cases below all diverge
    for name in ["traffic", "hotspot", "analyze"] {
        let scenario = find(name).expect("registered");
        let a = base();
        let mut b = base();
        b.workload.rate_hz = 4e6;
        b.workload.duration = Time::from_us(100);
        b.system.manager.eviction = EvictionPolicy::Fullest;
        assert_eq!(
            scenario.cache_key(&a),
            scenario.cache_key(&b),
            "{name}: execute-only knobs leaked into the cache key"
        );
        let prep_a = scenario.prepare(&a).unwrap();
        let prep_b = scenario.prepare(&b).unwrap();
        let cross = scenario.execute(prep_a.as_ref(), &b).unwrap();
        let own = scenario.execute(prep_b.as_ref(), &b).unwrap();
        assert_eq!(cross.to_json().pretty(), own.to_json().pretty(), "{name}");
    }

    let mut equal_key_pairs = 0usize;
    for case in 0..16u64 {
        let mut rng = Rng::new(0xCA57 + case);
        let scenario = find(*rng.choose(&["traffic", "burst", "hotspot", "analyze"]))
            .expect("registered");
        let mut a = base();
        let mut b = base();
        mutate(&mut a, &mut rng);
        let b_touched_plan = mutate(&mut b, &mut rng);
        let (ka, kb) = (scenario.cache_key(&a), scenario.cache_key(&b));
        if ka != kb {
            // keys may only separate when a plan input differed
            assert!(
                b_touched_plan || scenario.cache_key(&a) != scenario.cache_key(&base()),
                "case {case} ({}): keys diverged without a plan-input change",
                scenario.name()
            );
            continue;
        }
        equal_key_pairs += 1;
        // interchangeability: b executed on a's resources == b on its own
        let prep_a = scenario.prepare(&a).unwrap();
        let prep_b = scenario.prepare(&b).unwrap();
        let cross = scenario.execute(prep_a.as_ref(), &b).unwrap();
        let own = scenario.execute(prep_b.as_ref(), &b).unwrap();
        assert_eq!(
            cross.to_json().pretty(),
            own.to_json().pretty(),
            "case {case} ({}): equal keys but non-interchangeable resources",
            scenario.name()
        );
    }
    // the random half exercised at least some sharing too (the three
    // constructed pairs above guarantee the property is covered even if
    // this particular seed sequence produced none)
    let _ = equal_key_pairs;
}
