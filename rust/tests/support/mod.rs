//! Shared support for the integration-test binaries: the small reference
//! system and the cross-mode differential matrix driver.
//!
//! Not a test target itself — `differential_sync.rs` and
//! `determinism_queue.rs` include it with `#[path] mod support;`, so the
//! per-mode determinism gates are thin callers into **one** driver
//! ([`DiffMatrix`]) instead of copy-pasted loops. A new [`SyncMode`] is
//! picked up by every gate automatically via [`SyncMode::ALL`].

// Each including test binary compiles its own copy and uses a different
// subset of the driver's surface; what one binary leaves unused is load-
// bearing in the other.
#![allow(dead_code)]

use bss_extoll::coordinator::config::ReuseMode;
use bss_extoll::coordinator::scenario::find;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::sim::{QueueKind, SyncMode, Time};
use bss_extoll::wafer::system::SystemConfig;

/// The small reference system every determinism gate runs: 2 wafers on a
/// 2×2×1 torus, 400 µs of traffic — big enough for real cross-domain
/// load, small enough to run the full differential matrix in CI.
pub fn small() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 2,
        torus: TorusSpec::new(2, 2, 1),
        fpgas_per_wafer: 4,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.workload.rate_hz = 4e6;
    cfg.workload.sources_per_fpga = 16;
    cfg.workload.duration = Time::from_us(400);
    cfg
}

/// A differential determinism matrix: one scenario + base config, run
/// across sync modes × domain counts × queue backends, every cell
/// asserted byte-identical to the serial (`domains = 1`) reference
/// report. The driver behind every cross-mode gate in
/// `determinism_queue.rs` and `differential_sync.rs`.
///
/// Defaults cover the full current protocol matrix: all of
/// [`SyncMode::ALL`] × `domains = 1/2/4` × the wheel backend ×
/// `reuse = off/fabric` (PR 10: fabric rewind vs. cold rebuild — cells
/// alternate reuse modes, so warm cells also cross domain counts and
/// sync modes against the parked fabric of the previous cell). Narrow
/// or widen any axis with the builder methods; mutate the base config
/// (via [`DiffMatrix::new`]'s `cfg`) for fault/reliability variants.
pub struct DiffMatrix<'a> {
    scenario: &'a str,
    cfg: ExperimentConfig,
    label: String,
    modes: Vec<SyncMode>,
    domains: Vec<usize>,
    kinds: Vec<QueueKind>,
    reuses: Vec<ReuseMode>,
}

impl<'a> DiffMatrix<'a> {
    pub fn new(scenario: &'a str, cfg: ExperimentConfig) -> DiffMatrix<'a> {
        DiffMatrix {
            scenario,
            cfg,
            label: String::new(),
            modes: SyncMode::ALL.to_vec(),
            domains: vec![1, 2, 4],
            kinds: vec![QueueKind::Wheel],
            reuses: vec![ReuseMode::Off, ReuseMode::Fabric],
        }
    }

    /// Extra context prepended to assertion messages (e.g. the fault
    /// spec or reliability setting of this variant).
    pub fn label(mut self, label: &str) -> DiffMatrix<'a> {
        self.label = label.to_string();
        self
    }

    pub fn modes(mut self, modes: &[SyncMode]) -> DiffMatrix<'a> {
        self.modes = modes.to_vec();
        self
    }

    pub fn domains(mut self, domains: &[usize]) -> DiffMatrix<'a> {
        self.domains = domains.to_vec();
        self
    }

    pub fn kinds(mut self, kinds: &[QueueKind]) -> DiffMatrix<'a> {
        self.kinds = kinds.to_vec();
        self
    }

    pub fn reuses(mut self, reuses: &[ReuseMode]) -> DiffMatrix<'a> {
        self.reuses = reuses.to_vec();
        self
    }

    /// Run one cell of the matrix; returns the pretty report JSON.
    fn run_cell(&self, sync: SyncMode, domains: usize, kind: QueueKind, reuse: ReuseMode) -> String {
        let mut cfg = self.cfg.clone();
        cfg.sync = sync;
        cfg.domains = domains;
        cfg.queue = kind;
        cfg.reuse = reuse;
        find(self.scenario)
            .unwrap_or_else(|| panic!("scenario {} not registered", self.scenario))
            .run(&cfg)
            .unwrap_or_else(|e| {
                panic!(
                    "{}{} sync={} domains={domains} queue={kind:?} reuse={} run failed: {e:#}",
                    self.label,
                    self.scenario,
                    sync.as_str(),
                    reuse.as_str()
                )
            })
            .to_json()
            .pretty()
    }

    /// Run the whole matrix and assert every cell's report is
    /// byte-identical to the serial reference (`domains = 1` on the
    /// first configured backend, cold-built — the plain event loop, no
    /// partition machinery, no fabric rewind). Returns the reference
    /// JSON so callers can make content assertions on top.
    pub fn assert_identical(&self) -> String {
        let serial = self.run_cell(SyncMode::default(), 1, self.kinds[0], ReuseMode::Off);
        for &kind in &self.kinds {
            for &sync in &self.modes {
                for &domains in &self.domains {
                    for &reuse in &self.reuses {
                        let got = self.run_cell(sync, domains, kind, reuse);
                        assert_eq!(
                            serial,
                            got,
                            "{}{} report diverged from serial at sync={} domains={domains} \
                             queue={kind:?} reuse={}",
                            self.label,
                            self.scenario,
                            sync.as_str(),
                            reuse.as_str()
                        );
                    }
                }
            }
        }
        serial
    }
}
