//! Integration tests: Extoll fabric under adversarial load — saturation,
//! hot-spots, deadlock scenarios, and conservation under random traffic.

use bss_extoll::extoll::network::{build_torus, Fabric};
use bss_extoll::extoll::nic::{Nic, NicConfig};
use bss_extoll::extoll::packet::Packet;
use bss_extoll::extoll::torus::{NodeAddr, TorusSpec};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Actor, ActorId, Ctx, Sim, Time};
use bss_extoll::util::rng::Rng;

struct Sink {
    received: u64,
    bytes: u64,
    last_seq_from: std::collections::HashMap<u16, u64>,
    ooo: u64,
}

impl Sink {
    fn new() -> Self {
        Sink {
            received: 0,
            bytes: 0,
            last_seq_from: std::collections::HashMap::new(),
            ooo: 0,
        }
    }
}

impl Actor<Msg> for Sink {
    fn handle(&mut self, msg: Msg, _ctx: &mut Ctx<'_, Msg>) {
        if let Msg::Deliver(p) = msg {
            self.received += 1;
            self.bytes += p.payload_bytes as u64;
            // per-source ordering check (same src+dst ⇒ FIFO)
            let last = self.last_seq_from.entry(p.src.0).or_insert(0);
            if p.seq <= *last {
                self.ooo += 1;
            }
            *last = p.seq;
        }
    }
}

fn setup(dims: (u16, u16, u16), credits: u32) -> (Sim<Msg>, TorusSpec, Vec<ActorId>, Vec<ActorId>) {
    let mut sim = Sim::new();
    let spec = TorusSpec::new(dims.0, dims.1, dims.2);
    let cfg = NicConfig {
        credits_per_vc: credits,
        ..NicConfig::default()
    };
    let nics = build_torus(&mut sim, &spec, cfg);
    let sinks: Vec<ActorId> = nics
        .iter()
        .map(|&nic| {
            let s = sim.add(Sink::new());
            sim.get_mut::<Nic>(nic).attach_local(s);
            s
        })
        .collect();
    (sim, spec, nics, sinks)
}

#[test]
fn random_traffic_4x4x4_conservation_and_order() {
    let (mut sim, spec, nics, sinks) = setup((4, 4, 4), 4);
    let mut rng = Rng::new(2024);
    let n = spec.n_nodes();
    let total = 20_000u64;
    // per-source monotone seq AND monotone injection time, so the FIFO
    // check below observes the actual injection order per (src, dst)
    let mut seq_of = vec![0u64; n];
    let mut t_of = vec![Time::ZERO; n];
    for _ in 0..total {
        let s = rng.index(n);
        let d = rng.index(n);
        seq_of[s] += 1;
        t_of[s] += Time::from_ns(rng.range(10, 400));
        let p = Packet::raw(
            NodeAddr(s as u16),
            NodeAddr(d as u16),
            (rng.range(1, 31) * 16) as u32,
            Time::ZERO,
            seq_of[s],
        );
        sim.schedule(t_of[s], nics[s], Msg::Inject(p));
    }
    sim.run_to_completion();
    let mut received = 0;
    let mut ooo = 0;
    for &s in &sinks {
        let sink: &Sink = sim.get(s);
        received += sink.received;
        ooo += sink.ooo;
    }
    assert_eq!(received, total, "packets lost or duplicated");
    assert_eq!(ooo, 0, "per-source FIFO ordering violated");
}

#[test]
fn hotspot_traffic_backpressure_survives() {
    // everyone hammers node 0 with minimum credits
    let (mut sim, spec, nics, sinks) = setup((4, 4, 2), 1);
    let mut count = 0u64;
    for s in spec.nodes() {
        if s.0 == 0 {
            continue;
        }
        for k in 0..100 {
            count += 1;
            let p = Packet::raw(s, NodeAddr(0), 496, Time::ZERO, k);
            sim.schedule(Time::ZERO, nics[s.0 as usize], Msg::Inject(p));
        }
    }
    let steps = sim.run(50_000_000);
    assert!(steps < 50_000_000, "simulation did not converge (livelock?)");
    let sink: &Sink = sim.get(sinks[0]);
    assert_eq!(sink.received, count);
}

#[test]
fn antipodal_stress_every_ring_direction() {
    // worst case for the dateline scheme: all three axes wrap simultaneously
    let (mut sim, spec, nics, sinks) = setup((4, 4, 4), 1);
    let mut total = 0u64;
    for s in spec.nodes() {
        let (x, y, z) = spec.coords_of(s);
        let anti = spec.addr_of((x + 2) % 4, (y + 2) % 4, (z + 2) % 4);
        for k in 0..25 {
            total += 1;
            let p = Packet::raw(s, anti, 496, Time::ZERO, k);
            sim.schedule(Time::ZERO, nics[s.0 as usize], Msg::Inject(p));
        }
    }
    sim.run_to_completion();
    let received: u64 = sinks.iter().map(|&s| sim.get::<Sink>(s).received).sum();
    assert_eq!(received, total, "deadlock or loss under antipodal stress");
}

#[test]
fn throughput_approaches_link_rate_point_to_point() {
    let (mut sim, _, nics, sinks) = setup((2, 1, 1), 8);
    let n = 5_000u64;
    for i in 0..n {
        let p = Packet::raw(NodeAddr(0), NodeAddr(1), 496, Time::ZERO, i + 1);
        sim.schedule(Time::ZERO, nics[0], Msg::Inject(p));
    }
    sim.run_to_completion();
    let sink: &Sink = sim.get(sinks[1]);
    assert_eq!(sink.received, n);
    // 5k * 520B at ~97.7 Gbit/s ≈ 213 µs; allow 15% pipeline overhead
    let ideal = 5_000.0 * 520.0 * 8.0 / 97.745e9;
    let actual = sim.now.secs_f64();
    assert!(
        actual < ideal * 1.15,
        "throughput too low: {actual:.2e}s vs ideal {ideal:.2e}s"
    );
}

#[test]
fn fabric_handle_statistics() {
    let mut sim = Sim::new();
    let spec = TorusSpec::new(3, 3, 1);
    let fabric = Fabric::build(&mut sim, spec, NicConfig::default());
    let sinks: Vec<ActorId> = fabric
        .nics
        .iter()
        .map(|&nic| {
            let s = sim.add(Sink::new());
            sim.get_mut::<Nic>(nic).attach_local(s);
            s
        })
        .collect();
    let _ = sinks;
    for i in 0..100u64 {
        let p = Packet::raw(NodeAddr(0), NodeAddr(4), 256, Time::ZERO, i);
        sim.schedule(Time::from_ns(i * 50), fabric.nics[0], Msg::Inject(p));
    }
    sim.run_to_completion();
    assert_eq!(fabric.total_delivered(&sim), 100);
    let h = fabric.transit_histogram(&sim);
    assert_eq!(h.count(), 100);
    assert!(h.p50() > 0);
    assert!(fabric.max_link_utilization(&sim, sim.now) > 0.0);
}
