//! Integration tests of the experiment service mode (`bss-extoll
//! serve`): the TCP JSON-lines protocol, the FIFO worker pool, the
//! shared byte-budgeted resource cache, per-job quotas and
//! cancellation — and above all the determinism gate: reports served
//! by the pool must be byte-identical to the batch `Scenario::run`
//! path, with or without cache eviction pressure.

use std::collections::BTreeMap;

use bss_extoll::serve::client::{run_loadgen, Client, LoadgenConfig};
use bss_extoll::serve::protocol::{Event, QuotaReq, Request, Submission};
use bss_extoll::serve::{ServeConfig, Server};

/// A small machine so one submission costs milliseconds.
const SMALL: &str = "n_wafers=2;torus=2x2x1;fpgas_per_wafer=4;concentrators_per_wafer=2;\
                     sources_per_fpga=8;duration_s=0.0002;rate_hz=2e6";

/// Long enough (hundreds of thousands of spikes) that a cancel or a
/// quota lands mid-run with margin.
const LONG: &str = "n_wafers=2;torus=2x2x1;fpgas_per_wafer=4;concentrators_per_wafer=2;\
                    sources_per_fpga=8;duration_s=0.005;rate_hz=2e6";

fn spawn_server(workers: usize, cache_bytes: u64) -> (bss_extoll::serve::ServerHandle, String) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        cache_bytes,
        max_wall_ms: 0,
        max_events: 0,
    })
    .expect("bind ephemeral server");
    let addr = server.local_addr().to_string();
    (server.spawn(), addr)
}

fn submit(client: &mut Client, scenario: &str, set: &str, tag: &str, quota: QuotaReq) {
    client
        .send(&Request::Submit(Submission {
            scenario: scenario.to_string(),
            set: set.to_string(),
            config: None,
            tag: tag.to_string(),
            quota,
        }))
        .expect("send submit");
}

/// Read events until `tag`'s job reaches a terminal status; returns the
/// terminal event.
fn wait_terminal(client: &mut Client, tag: &str) -> Event {
    let mut job_id = None;
    loop {
        let ev = client.next_event().expect("next event");
        match &ev {
            Event::Queued { job, tag: t } if t == tag => job_id = Some(*job),
            Event::Done { job, .. } | Event::Cancelled { job } if Some(*job) == job_id => {
                return ev;
            }
            Event::Rejected { job, tag: t, .. }
                if (job.is_some() && *job == job_id) || t == tag =>
            {
                return ev;
            }
            _ => {}
        }
    }
}

#[test]
fn loadgen_round_completes_with_byte_identical_reports() {
    let (handle, addr) = spawn_server(4, 0);
    let outcome = run_loadgen(&LoadgenConfig {
        addr,
        submissions: 24,
        connections: 4,
        verify: true,
        shutdown_after: true,
        ..LoadgenConfig::default()
    })
    .expect("loadgen round");
    handle.join().expect("clean shutdown");

    assert_eq!(outcome.completed, 24, "every submission must complete");
    assert_eq!(outcome.rejected, 0);
    assert_eq!(outcome.cancelled, 0);
    assert!(outcome.verified > 0, "verification must actually run");
    assert!(
        outcome.byte_identical(),
        "{} served reports differ from the batch path",
        outcome.mismatches
    );
    // the cross-submission cache must actually share: far fewer
    // prepares than submissions
    let cache = outcome.cache.as_ref().expect("stats event captured");
    let prepared = cache.at(&["cache", "prepared"]).unwrap().as_u64().unwrap();
    let reused = cache.at(&["cache", "reused"]).unwrap().as_u64().unwrap();
    assert!(
        prepared < 24,
        "cache never shared: {prepared} prepares for 24 submissions"
    );
    assert_eq!(prepared + reused, 24);
}

/// The eviction acceptance gate: a cache squeezed to a 1-byte budget
/// (every entry oversized, evicted immediately, re-prepared per job)
/// must serve byte-identical reports to an unbounded cache.
#[test]
fn eviction_then_reprepare_serves_identical_reports() {
    // distinct machine shapes = distinct cache keys, so the tiny
    // budget actually thrashes
    let sets: Vec<String> = (0..3)
        .flat_map(|i| {
            let shape = format!(
                "n_wafers=2;torus=2x2x1;fpgas_per_wafer=4;concentrators_per_wafer=2;\
                 sources_per_fpga={};duration_s=0.0002;rate_hz=2e6",
                4 << i
            );
            // two submissions per shape: the second is a cache hit on
            // the unbounded server, a re-prepare on the tiny one
            [shape.clone(), shape]
        })
        .collect();

    let run_against = |cache_bytes: u64| -> BTreeMap<String, String> {
        let (handle, addr) = spawn_server(2, cache_bytes);
        let mut client = Client::connect(&addr).expect("connect");
        let mut reports = BTreeMap::new();
        for (i, set) in sets.iter().enumerate() {
            let tag = format!("j{i}");
            submit(&mut client, "traffic", set, &tag, QuotaReq::default());
            match wait_terminal(&mut client, &tag) {
                Event::Done { report, .. } => {
                    reports.insert(tag, report.to_string());
                }
                other => panic!("job {tag} ended as {other:?}"),
            }
        }
        handle.stop();
        handle.join().expect("clean shutdown");
        reports
    };

    let unlimited = run_against(0);
    let tiny = run_against(1);
    assert_eq!(
        unlimited, tiny,
        "eviction-then-re-prepare changed served report bytes"
    );
}

#[test]
fn cancel_mid_run_leaves_pool_healthy() {
    // one worker: the long job occupies it, the follow-up job proves
    // the worker survived the cancellation
    let (handle, addr) = spawn_server(1, 0);
    let mut client = Client::connect(&addr).expect("connect");
    submit(&mut client, "traffic", LONG, "victim", QuotaReq::default());

    // wait until the job is actually running, then cancel it
    let mut job_id = None;
    loop {
        match client.next_event().expect("next event") {
            Event::Queued { job, tag } if tag == "victim" => job_id = Some(job),
            Event::Running { job, .. } if Some(job) == job_id => break,
            Event::Done { .. } => panic!("job finished before it could be cancelled"),
            _ => {}
        }
    }
    let victim = job_id.expect("queued event seen");
    client.send(&Request::Cancel { job: victim }).expect("send cancel");
    loop {
        match client.next_event().expect("next event") {
            Event::Cancelled { job } if job == victim => break,
            Event::Done { job, .. } if job == victim => {
                panic!("cancelled job ran to completion")
            }
            _ => {}
        }
    }

    // the pool must keep serving
    submit(&mut client, "traffic", SMALL, "after", QuotaReq::default());
    match wait_terminal(&mut client, "after") {
        Event::Done { .. } => {}
        other => panic!("post-cancel job ended as {other:?}"),
    }
    handle.stop();
    handle.join().expect("clean shutdown");
}

#[test]
fn bad_submissions_are_rejected_without_killing_the_server() {
    let (handle, addr) = spawn_server(2, 0);
    let mut client = Client::connect(&addr).expect("connect");

    // malformed line: error event, the connection (and server) survive
    {
        use std::io::{BufRead, BufReader, Write};
        let mut s = std::net::TcpStream::connect(&addr).expect("raw connect");
        s.write_all(b"this is not json\n").expect("write garbage");
        s.write_all(b"{\"cmd\":\"stats\"}\n").expect("write stats");
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).expect("read error event");
        assert!(line.contains("\"event\":\"error\""), "got {line:?}");
        line.clear();
        r.read_line(&mut line).expect("read stats event");
        assert!(line.contains("\"event\":\"stats\""), "got {line:?}");
    }

    // unknown scenario
    submit(&mut client, "no_such_scenario", "", "u1", QuotaReq::default());
    match wait_terminal(&mut client, "u1") {
        Event::Rejected { reason, .. } => {
            assert!(reason.contains("unknown scenario"), "reason: {reason}")
        }
        other => panic!("expected rejection, got {other:?}"),
    }

    // unknown config knob
    submit(&mut client, "traffic", "no_such_knob=1", "u2", QuotaReq::default());
    match wait_terminal(&mut client, "u2") {
        Event::Rejected { reason, .. } => {
            assert!(reason.contains("bad set"), "reason: {reason}")
        }
        other => panic!("expected rejection, got {other:?}"),
    }

    // and the server still completes real work afterwards
    submit(&mut client, "traffic", SMALL, "ok", QuotaReq::default());
    match wait_terminal(&mut client, "ok") {
        Event::Done { .. } => {}
        other => panic!("valid job after rejections ended as {other:?}"),
    }
    handle.stop();
    handle.join().expect("clean shutdown");
}

#[test]
fn quota_exceeded_jobs_surface_clean_rejections() {
    let (handle, addr) = spawn_server(1, 0);
    let mut client = Client::connect(&addr).expect("connect");

    // simulated-event budget: 1 event is always exhausted by the first
    // checkpoint of the long job
    submit(
        &mut client,
        "traffic",
        LONG,
        "ev",
        QuotaReq {
            max_wall_ms: None,
            max_events: Some(1),
        },
    );
    match wait_terminal(&mut client, "ev") {
        Event::Rejected { reason, .. } => {
            assert!(reason.contains("quota"), "reason: {reason}")
        }
        other => panic!("expected quota rejection, got {other:?}"),
    }

    // wall-clock budget on a job that needs far longer than 1 ms
    submit(
        &mut client,
        "traffic",
        LONG,
        "wall",
        QuotaReq {
            max_wall_ms: Some(1),
            max_events: None,
        },
    );
    match wait_terminal(&mut client, "wall") {
        Event::Rejected { reason, .. } => {
            assert!(reason.contains("quota"), "reason: {reason}")
        }
        other => panic!("expected quota rejection, got {other:?}"),
    }

    // the worker survives quota kills
    submit(&mut client, "traffic", SMALL, "ok", QuotaReq::default());
    match wait_terminal(&mut client, "ok") {
        Event::Done { .. } => {}
        other => panic!("post-quota job ended as {other:?}"),
    }
    handle.stop();
    handle.join().expect("clean shutdown");
}
