//! The cross-mode differential harness (PR 8): every synchronization
//! protocol the PDES implements — serial, windowed, channel clocks,
//! barrier-free — must produce **byte-identical** reports on the same
//! config, across domain counts, queue backends, fault injection and
//! the link-reliability layer. One matrix driver
//! ([`support::DiffMatrix`]) replaces the per-mode gates that used to be
//! copy-pasted through `determinism_queue.rs`; those gates are now thin
//! callers into the same driver, so adding a sync mode to
//! [`SyncMode::ALL`] automatically subjects it to every gate here.
//!
//! This file is the PR 8 acceptance gate for `sync=free`: reports
//! byte-identical to serial across domains=1/2/4 × heap/wheel × fault
//! on/off × reliability off/link.

#[path = "support/mod.rs"]
mod support;

use bss_extoll::extoll::link::Reliability;
use bss_extoll::fault::FaultConfig;
use bss_extoll::sim::{QueueKind, SyncMode};
use support::{small, DiffMatrix};

/// Fault spec exercising every mechanism the fault model has: cable
/// failures (re-routing), packet loss, serialization degradation and
/// latency jitter — the same spec the PR 6/PR 7 gates pinned.
const FAULT_SPEC: &str = "fail:0.1|loss:0.02|degrade:0.2|degrade_factor:2.0|jitter_ns:30";

fn faulted(spec: &str) -> bss_extoll::coordinator::ExperimentConfig {
    let mut cfg = small();
    cfg.fault = FaultConfig::parse_spec(spec).unwrap_or_else(|e| panic!("fault spec: {e}"));
    cfg
}

/// Healthy fabric, both backends, full mode × domain matrix.
#[test]
fn traffic_matrix_healthy() {
    let serial = DiffMatrix::new("traffic", small())
        .kinds(&[QueueKind::Wheel, QueueKind::Heap])
        .assert_identical();
    assert!(serial.contains("rx_events"));
}

/// Every fault mechanism live, both backends, full mode × domain matrix.
#[test]
fn traffic_matrix_faulted() {
    let serial = DiffMatrix::new("fault_sweep", faulted(FAULT_SPEC))
        .label("fault ")
        .kinds(&[QueueKind::Wheel, QueueKind::Heap])
        .assert_identical();
    assert!(serial.contains("deliverability"));
}

/// Link-level reliability recovering lost packets (retransmission
/// timers, ACK/NACK control frames live), both backends, full matrix.
#[test]
fn traffic_matrix_reliability_link() {
    let mut cfg = faulted(FAULT_SPEC);
    cfg.system.nic.reliability = Reliability::Link;
    let serial = DiffMatrix::new("reliability_sweep", cfg)
        .label("reliability=link ")
        .kinds(&[QueueKind::Wheel, QueueKind::Heap])
        .assert_identical();
    assert!(serial.contains("recovered_events"));
    assert!(serial.contains("retransmissions"));
}

/// Faulted fabric with the reliability layer explicitly off — the
/// fourth corner of the fault × reliability acceptance square.
#[test]
fn traffic_matrix_faulted_reliability_off() {
    let mut cfg = faulted(FAULT_SPEC);
    cfg.system.nic.reliability = Reliability::Off;
    DiffMatrix::new("fault_sweep", cfg)
        .label("reliability=off ")
        .kinds(&[QueueKind::Wheel, QueueKind::Heap])
        .assert_identical();
}

/// Burst and hotspot traffic shapes through the full mode matrix (wheel
/// backend, the default) — the workloads whose per-mode gates lived in
/// `determinism_queue.rs` before the driver existed.
#[test]
fn burst_and_hotspot_matrix() {
    for scenario in ["burst", "hotspot"] {
        DiffMatrix::new(scenario, small()).assert_identical();
    }
}

/// The free mode alone, swept dense (domains up to the 4-node torus's
/// limit) on both backends — a tighter loop for bisecting a free-mode
/// regression without running the whole matrix.
#[test]
fn free_mode_focused() {
    DiffMatrix::new("traffic", small())
        .modes(&[SyncMode::Free])
        .domains(&[2, 3, 4])
        .kinds(&[QueueKind::Wheel, QueueKind::Heap])
        .assert_identical();
}

/// PR 10 acceptance gate: the rack-scale `microcircuit_rack` scenario
/// (the full 20-wafer, 960-FPGA machine) is byte-identical across
/// domains = 1/2/4 × every sync mode × reset-reuse vs. cold rebuild.
/// The workload window is cut to 20 µs so the ~19-cell matrix over a
/// 960-FPGA fabric stays CI-sized; the machine shape is NOT scaled
/// down — that is the point of the gate.
#[test]
fn rack_matrix_full_scale() {
    let rack = bss_extoll::coordinator::scenario::find("microcircuit_rack").unwrap();
    let mut cfg = rack.default_config();
    assert!(cfg.system.n_wafers >= 20, "rack gate must run at rack scale");
    cfg.workload.duration = bss_extoll::sim::Time::from_us(20);
    let serial = DiffMatrix::new("microcircuit_rack", cfg).assert_identical();
    assert!(serial.contains("bytes_per_neuron"));
    assert!(serial.contains("resident_bytes"));
}
