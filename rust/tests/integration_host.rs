//! Integration tests: host ring-buffer sessions over multi-hop torus
//! routes, concurrent channels, and pathological timing.

use bss_extoll::extoll::network::Fabric;
use bss_extoll::extoll::nic::{Nic, NicConfig};
use bss_extoll::extoll::torus::{NodeAddr, TorusSpec};
use bss_extoll::host::host::{ChannelConfig, Host, HostConfig};
use bss_extoll::host::stream::{StreamConfig, StreamSource, TIMER_PRODUCE};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Sim, Time};

/// Two FPGA streams on different torus nodes feed two channels of one
/// host across a 3D torus; both must complete loss-free.
#[test]
fn two_streams_multihop_to_one_host() {
    let mut sim: Sim<Msg> = Sim::new();
    let fabric = Fabric::build(&mut sim, TorusSpec::new(3, 3, 1), NicConfig::default());
    let host_node = NodeAddr(8); // corner; streams at 0 and 4
    let total = 512 * 1024u64;

    let mut streams = Vec::new();
    for (i, src) in [NodeAddr(0), NodeAddr(4)].into_iter().enumerate() {
        let ch = (i + 1) as u16;
        let stream = sim.add(StreamSource::new(StreamConfig {
            node: src,
            host_node,
            channel: ch,
            nla_base: 0x10000 * ch as u64,
            ring_size: 1 << 15,
            chunk_bytes: 2048,
            rate_bps: 2e9,
            total_bytes: total,
        }));
        sim.get_mut::<StreamSource>(stream).attach_nic(fabric.nics[src.0 as usize]);
        sim.get_mut::<Nic>(fabric.nics[src.0 as usize]).attach_local(stream);
        sim.schedule(Time::ZERO, stream, Msg::Timer(TIMER_PRODUCE));
        streams.push((stream, src, ch));
    }
    let host = sim.add(Host::new(HostConfig {
        node: host_node,
        consume_rate: 0.0,
        ..HostConfig::default()
    }));
    {
        let h = sim.get_mut::<Host>(host);
        h.attach_nic(fabric.nics[host_node.0 as usize]);
        for &(_, src, ch) in &streams {
            h.add_channel(ChannelConfig {
                id: ch,
                nla_base: 0x10000 * ch as u64,
                ring_size: 1 << 15,
                producer_node: src,
                credit_batch: 1 << 13,
            });
        }
    }
    sim.get_mut::<Nic>(fabric.nics[host_node.0 as usize]).attach_local(host);

    let steps = sim.run(500_000_000);
    assert!(steps < 500_000_000, "did not converge");
    let h: &Host = sim.get(host);
    assert_eq!(h.stats.bytes_consumed, 2 * total, "bytes lost across channels");
    for &(stream, _, _) in &streams {
        let s: &StreamSource = sim.get(stream);
        assert_eq!(s.stats.bytes_produced, total);
    }
}

/// A ring smaller than one chunk would deadlock a naive implementation;
/// the producer must reject the oversized write loudly instead.
#[test]
#[should_panic(expected = "write of")]
fn chunk_larger_than_ring_is_rejected() {
    let mut ring = bss_extoll::host::ringbuf::RingProducer::new(0, 1024);
    let _ = ring.write(2048);
}

/// Tiny ring + tiny credit batch: heavy credit traffic, still loss-free.
#[test]
fn tiny_ring_heavy_credit_chatter() {
    let mut sim: Sim<Msg> = Sim::new();
    let fabric = Fabric::build(&mut sim, TorusSpec::new(2, 1, 1), NicConfig::default());
    let total = 64 * 1024u64;
    let stream = sim.add(StreamSource::new(StreamConfig {
        node: NodeAddr(0),
        host_node: NodeAddr(1),
        ring_size: 4096,
        chunk_bytes: 1024,
        rate_bps: 10e9,
        total_bytes: total,
        ..StreamConfig::default()
    }));
    let host = sim.add(Host::new(HostConfig {
        node: NodeAddr(1),
        consume_rate: 0.0,
        ..HostConfig::default()
    }));
    {
        let h = sim.get_mut::<Host>(host);
        h.attach_nic(fabric.nics[1]);
        h.add_channel(ChannelConfig {
            id: 1,
            nla_base: 0x10000,
            ring_size: 4096,
            producer_node: NodeAddr(0),
            credit_batch: 512, // tiny: one credit per half-chunk
        });
    }
    sim.get_mut::<StreamSource>(stream).attach_nic(fabric.nics[0]);
    sim.get_mut::<Nic>(fabric.nics[0]).attach_local(stream);
    sim.get_mut::<Nic>(fabric.nics[1]).attach_local(host);
    sim.schedule(Time::ZERO, stream, Msg::Timer(TIMER_PRODUCE));
    sim.run(200_000_000);
    let h: &Host = sim.get(host);
    assert_eq!(h.stats.bytes_consumed, total);
    let s: &StreamSource = sim.get(stream);
    // the 4 KiB ring forces many small credit exchanges (batching caps
    // them at roughly one per driver poll)
    assert!(
        s.stats.credit_notifications > 4,
        "expected repeated credit exchange, got {}",
        s.stats.credit_notifications
    );
    assert!(s.stats.stall_episodes > 0, "a 4 KiB ring at 10 Gbit/s must stall");
}
