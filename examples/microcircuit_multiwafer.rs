//! End-to-end driver: multi-wafer cortical
//! microcircuit with LIF dynamics in AOT-compiled JAX/Pallas artifacts,
//! every inter-wafer spike crossing the simulated Extoll fabric.
//!
//! This is the repository's full-stack proof: L1 Pallas kernels → L2 JAX
//! model → HLO artifacts → rust PJRT runtime → FPGA aggregation buckets →
//! torus fabric → RX multicast → back into the neuron models.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example microcircuit_multiwafer [steps] [artifact]

// The deprecated driver wrappers stay supported for one release.
#![allow(deprecated)]

use bss_extoll::coordinator::{run_microcircuit, ExperimentConfig};
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::wafer::system::SystemConfig;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifact = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "shard_256x1024".to_string());

    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 2,
        torus: TorusSpec::new(2, 2, 1),
        fpgas_per_wafer: 2,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.neuro.artifact = artifact.clone();
    cfg.neuro.steps = steps;

    println!("=== multi-wafer cortical microcircuit ===");
    println!("artifact: {artifact}, steps: {steps}");
    println!("machine: 2 wafers x 2 FPGAs on a 2x2 torus (4 shards)\n");

    let r = run_microcircuit(&cfg)?;

    println!("neurons:            {}", r.n_neurons);
    println!("spikes total:       {}", r.spikes_total);
    println!(
        "mean rate:          {:.4} spk/neuron/step ({:.2} Hz at 0.1 ms bio dt)",
        r.mean_rate,
        r.mean_rate * 10_000.0
    );
    println!("fabric events:      {}", r.fabric_events);
    println!("delivered:          {}", r.delivered_events);
    println!("mean events/packet: {:.2}", r.mean_batch);
    println!("deadline misses:    {}", r.deadline_misses);
    println!(
        "fabric latency:     p50 {:.0} ns, p99 {:.0} ns",
        r.latency.p50() as f64 / 1e3,
        r.latency.p99() as f64 / 1e3
    );
    println!(
        "wall time:          {:.2}s PJRT + {:.2}s DES",
        r.pjrt_seconds, r.des_seconds
    );

    // activity curve, 10 buckets
    println!("\nactivity (spikes per step, {}-step buckets):", steps / 10);
    let bucket = (steps / 10).max(1);
    for (i, chunk) in r.spikes_per_step.chunks(bucket).enumerate() {
        let mean = chunk.iter().map(|&x| x as f64).sum::<f64>() / chunk.len() as f64;
        let bar = "#".repeat((mean / 4.0).min(60.0) as usize);
        println!("  step {:>4}: {:>7.1} {bar}", i * bucket, mean);
    }

    anyhow::ensure!(r.spikes_total > 0, "network was silent");
    anyhow::ensure!(
        r.delivered_events == r.fabric_events,
        "fabric lost events: {} delivered of {}",
        r.delivered_events,
        r.fabric_events
    );
    println!("\nmicrocircuit e2e OK — zero event loss across the fabric");
    Ok(())
}
