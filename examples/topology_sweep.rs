//! Topology sweep (paper Fig. 1): how many concentrator nodes per wafer?
//!
//! The paper proposes 8 concentrators × 6 FPGAs per wafer as "optimal …
//! regarding bandwidth utilisation". This example sweeps the fan-in over
//! the full-scale cortical-microcircuit traffic matrix and shows where
//! each alternative saturates — concentrator ingress vs torus links.
//!
//! Run: `cargo run --release --example topology_sweep`
//!
//! The same flow-level analysis is registered as the `analyze` scenario:
//! `bss-extoll run analyze --set "n_wafers=4;torus=4x4x2"`, and the
//! packet-level equivalent sweeps through the registry CLI, e.g.
//! `bss-extoll sweep --scenario traffic
//!  --grid "concentrators_per_wafer=4,8,16" --jobs 4` (knob reference:
//! docs/TUNING.md).

use bss_extoll::extoll::analysis::FlowAnalysis;
use bss_extoll::extoll::nic::NicConfig;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::msg::Msg;
use bss_extoll::sim::Sim;
use bss_extoll::util::bench::Table;
use bss_extoll::wafer::system::{System, SystemConfig};
use bss_extoll::workload::microcircuit::{Microcircuit, Placement};

fn main() {
    let wafers = 4usize;
    let mc = Microcircuit::new(1.0);
    println!(
        "cortical microcircuit: {} neurons, {:.2e} spikes/s total",
        mc.total_neurons(),
        mc.total_rate_hz()
    );
    println!("machine: {wafers} wafers, 48 FPGAs each\n");

    // BrainScaleS runs 10^3–10^4x faster than biology; the interconnect
    // must carry the wall-clock (accelerated) spike rates.
    for &speedup in &[1e3, 1e4] {
        let mut table = Table::new(
            &format!(
                "Fig.1 topology sweep — concentrators per wafer (48 FPGAs/wafer,                  {wafers} wafers, speedup {speedup:.0}x)"
            ),
            &[
                "conc/wafer",
                "fpga/conc",
                "torus",
                "offered Gbit/s",
                "peak link util",
                "conc ingress util",
                "sustainable",
            ],
        );

        for &conc in &[1usize, 2, 4, 8, 16, 48] {
            let nodes_needed = wafers * conc;
            // choose a torus with enough nodes, roughly cubic
            let torus = pick_torus(nodes_needed);
            let cfg = SystemConfig {
                n_wafers: wafers,
                torus,
                fpgas_per_wafer: 48,
                concentrators_per_wafer: conc,
                ..SystemConfig::default()
            };
            let mut sim: Sim<Msg> = Sim::new();
            let sys = System::build(&mut sim, cfg);
            let placement = Placement::spread(&mc, &sys);
            let flows = placement.flows_accelerated(&mc, 32.0, speedup);
            let nic = NicConfig::default();
            let a = FlowAnalysis::run(&torus, &flows, nic.link_gbps());
            // the local link of each torus node carries the deliveries of
            // 48/conc FPGAs — the concentrator-ingress bottleneck
            let ingress = a.max_local_utilization(nic.link_gbps());
            let sustainable = a.sustainable_fraction().min(1.0 / ingress.max(1e-9)).min(1.0);
            table.row(vec![
                conc.to_string(),
                (48 / conc).to_string(),
                format!("{}x{}x{}", torus.nx, torus.ny, torus.nz),
                format!("{:.2}", a.total_offered_gbps),
                format!("{:.4}", a.max_utilization()),
                format!("{:.4}", ingress),
                format!("{:.3}", sustainable),
            ]);
        }
        table.print();
    }

    println!(
        "\nreading: fewer concentrators → each torus node carries more FPGA\n\
         traffic (ingress bottleneck); more concentrators → more nodes, more\n\
         hops, more torus links per flow. The paper's 8/wafer sits at the\n\
         knee: spike traffic fits comfortably while the node count (and\n\
         Tourmalet cost) stays at 8 per wafer."
    );
}

fn pick_torus(nodes: usize) -> TorusSpec {
    // smallest of the preset shapes that fits
    for &(x, y, z) in &[
        (2u16, 2u16, 1u16),
        (2, 2, 2),
        (4, 2, 2),
        (4, 4, 2),
        (4, 4, 4),
        (8, 4, 4),
        (8, 8, 4),
    ] {
        if (x as usize) * (y as usize) * (z as usize) >= nodes {
            return TorusSpec::new(x, y, z);
        }
    }
    TorusSpec::new(16, 8, 8)
}
