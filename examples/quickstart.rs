//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! Builds a two-wafer system on a tiny torus, programs one spike route
//! across wafers, pushes a handful of events through the full TX pipeline
//! (lookup → aggregation bucket → egress → torus → RX multicast), and
//! prints what happened at each layer.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! For ready-made experiments use the `Scenario` registry CLI instead of
//! hand-building a system: `bss-extoll run <scenario>` (list with
//! `run --list`), parameter grids with `bss-extoll sweep --jobs N`, knobs
//! via `--set "key=v;..."` (docs/TUNING.md). The spike's full journey
//! through the layers below is narrated in docs/ARCHITECTURE.md §3.

use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::fpga::event::SpikeEvent;
use bss_extoll::fpga::fpga::Fpga;
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Sim, Time};
use bss_extoll::wafer::system::{System, SystemConfig};

fn main() {
    // 1. a 2-wafer machine: 4 concentrator nodes on a 2x2x1 torus,
    //    3 FPGAs per concentrator (down-scaled from the paper's 8x6)
    let mut sim: Sim<Msg> = Sim::new();
    let sys = System::build(
        &mut sim,
        SystemConfig {
            n_wafers: 2,
            torus: TorusSpec::new(2, 2, 1),
            fpgas_per_wafer: 6,
            concentrators_per_wafer: 2,
            ..SystemConfig::default()
        },
    );
    println!(
        "built {} wafers, {} FPGAs, {}-node torus",
        sys.wafers.len(),
        sys.n_fpgas(),
        sys.cfg.torus.n_nodes()
    );

    // 2. program a route: wafer 0 / FPGA 0 / HICANN 2 / pulse 0x155
    //    → wafer 1 / FPGA 4, GUID 1234, multicast to HICANNs {0,1,7}
    sys.program_route(&mut sim, (0, 0), 2, 0x155, (1, 4), 1234, 0b1000_0011, 0x044);

    // 3. emit 10 spikes, 1 µs apart, deadlines ~20 µs out
    let src = sys.wafers[0].fpgas[0];
    for i in 0..10u64 {
        let deadline = ((i * 210 + 4200) & 0x7FFF) as u16; // systime units
        sim.schedule(
            Time::from_us(i),
            src,
            Msg::HicannEvent(SpikeEvent::new(2, 0x155, deadline)),
        );
    }

    // 4. run the simulation to quiescence
    sim.run_until(Time::from_ms(1));
    println!("simulated {} (processed {} events)", sim.now, sim.processed());

    // 5. inspect each layer
    let tx: &Fpga = sim.get(sys.wafers[0].fpgas[0]);
    println!("\nTX FPGA (wafer 0, fpga 0):");
    println!("  events in:        {}", tx.stats.events_in);
    println!("  packets out:      {}", tx.stats.packets_out);
    println!("  events/packet:    {:.2}", tx.stats.mean_batch());
    println!(
        "  flushes deadline/full: {}/{}",
        tx.mgr.stats.flush_deadline, tx.mgr.stats.flush_full
    );

    let rx: &Fpga = sim.get(sys.wafers[1].fpgas[4]);
    println!("\nRX FPGA (wafer 1, fpga 4):");
    println!("  packets in:       {}", rx.stats.rx_packets);
    println!("  events in:        {}", rx.stats.rx_events);
    println!(
        "  per-HICANN deliveries: {:?}",
        rx.stats.playback.per_hicann
    );
    println!(
        "  e2e latency p50:  {:.1} ns",
        rx.stats.playback.latency_ps.p50() as f64 / 1e3
    );
    println!("  deadline misses:  {}", rx.stats.playback.deadline_misses);

    assert_eq!(tx.stats.events_in, 10);
    assert_eq!(rx.stats.rx_events, 10, "all spikes must arrive");
    println!("\nquickstart OK — all 10 spikes crossed the fabric");
}
