//! Scenario-API tour: dispatch experiments generically through the
//! registry, drive the two-phase prepare/execute lifecycle by hand, then
//! run a 2×2 parameter sweep and inspect its artifacts + cache counters.
//!
//! Adding a scenario to the system is one type implementing
//! `coordinator::Scenario` plus one line in `scenario::registry()` —
//! after that it is runnable here, from `bss-extoll run <name>`, and
//! sweepable from `bss-extoll sweep`.
//!
//! Run: `cargo run --release --example scenario_sweep`

use bss_extoll::coordinator::scenario;
use bss_extoll::coordinator::sweep::SweepRunner;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::sim::Time;
use bss_extoll::wafer::system::SystemConfig;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 2,
        torus: TorusSpec::new(2, 2, 1),
        fpgas_per_wafer: 4,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.workload.rate_hz = 4e6;
    cfg.workload.sources_per_fpga = 16;
    cfg.workload.duration = Time::from_us(500);

    // 1. the registry: every experiment behind one static trait table,
    //    each declaring its metric schema up front
    println!("registered scenarios:");
    for s in scenario::registry() {
        println!(
            "  {:<14} {} ({} metrics)",
            s.name(),
            s.about(),
            s.metrics().len()
        );
    }

    // 2. generic dispatch — run() = prepare + execute in one call
    let report = scenario::find("hotspot").expect("registered").run(&cfg)?;
    report.print();

    // 3. the two-phase lifecycle by hand: prepare once (routes, seeds),
    //    execute at several operating points against the same resources
    let traffic = scenario::find("traffic").expect("registered");
    println!("\ncache key: {}", traffic.cache_key(&cfg));
    let prepared = traffic.prepare(&cfg)?;
    for rate in [1e6, 8e6] {
        let mut point = cfg.clone();
        point.workload.rate_hz = rate;
        let r = traffic.execute(prepared.as_ref(), &point)?;
        println!(
            "rate {:>9.0}: mean_batch {:.2} events/packet",
            rate,
            r.get_f64("mean_batch").unwrap_or(f64::NAN)
        );
    }

    // 4. a 2×2 sweep: rate × generator kind, one report row per point.
    //    Neither axis is a plan input — and burst shares traffic's plan
    //    family — so the runner's resource cache prepares exactly once.
    let runner = SweepRunner::new(cfg)
        .axis("rate_hz", &["1e6", "8e6"])
        .axis("generator", &["poisson", "burst"]);
    let result = runner.run(traffic)?;
    result.table().print();
    println!("\nCSV artifact:\n{}", result.to_csv());
    println!(
        "resource cache: {} prepared, {} reused",
        result.cache.misses, result.cache.hits
    );
    anyhow::ensure!(result.points.len() == 4, "expected a 2×2 grid");
    anyhow::ensure!(result.cache.misses == 1, "expected one shared plan");
    anyhow::ensure!(result.cache.hits == 3, "expected three cache hits");
    println!("scenario_sweep OK");
    Ok(())
}
