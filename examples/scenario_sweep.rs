//! Scenario-API tour: dispatch experiments generically through the
//! registry, then run a 2×2 parameter sweep and print its artifacts.
//!
//! Adding a scenario to the system is one type implementing
//! `coordinator::Scenario` plus one line in `scenario::registry()` —
//! after that it is runnable here, from `bss-extoll run <name>`, and
//! sweepable from `bss-extoll sweep`.
//!
//! Run: `cargo run --release --example scenario_sweep`

use bss_extoll::coordinator::scenario;
use bss_extoll::coordinator::sweep::SweepRunner;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::torus::TorusSpec;
use bss_extoll::sim::Time;
use bss_extoll::wafer::system::SystemConfig;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 2,
        torus: TorusSpec::new(2, 2, 1),
        fpgas_per_wafer: 4,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.workload.rate_hz = 4e6;
    cfg.workload.sources_per_fpga = 16;
    cfg.workload.duration = Time::from_us(500);

    // 1. the registry: every experiment behind one trait
    println!("registered scenarios:");
    for s in scenario::registry() {
        println!("  {:<14} {}", s.name(), s.about());
    }

    // 2. generic dispatch — same call shape for every scenario
    let report = scenario::find("hotspot").expect("registered").run(&cfg)?;
    report.print();

    // 3. a 2×2 sweep: rate × generator kind, one report row per point
    let runner = SweepRunner::new(cfg)
        .axis("rate_hz", &["1e6", "8e6"])
        .axis("generator", &["poisson", "burst"]);
    let result = runner.run(scenario::find("traffic").unwrap().as_ref())?;
    result.table().print();
    println!("\nCSV artifact:\n{}", result.to_csv());
    anyhow::ensure!(result.points.len() == 4, "expected a 2×2 grid");
    println!("scenario_sweep OK");
    Ok(())
}
