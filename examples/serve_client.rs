//! Service-mode client walkthrough: submit experiments to a running
//! `bss-extoll serve` instance programmatically and consume the
//! streamed status lifecycle (`queued → preparing → running → done`).
//!
//! The example is self-contained: it spins the server up in-process on
//! an ephemeral port, so there is nothing to start beforehand.
//!
//! Run: `cargo run --release --example serve_client`
//!
//! Against an external server, the same client code works unchanged —
//! point `Client::connect` at its address (start one with
//! `bss-extoll serve --addr 127.0.0.1:7411 --workers 4`). The wire
//! grammar is documented in docs/ARCHITECTURE.md §7.

use bss_extoll::serve::client::Client;
use bss_extoll::serve::protocol::{Event, QuotaReq, Request, Submission};
use bss_extoll::serve::{ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    // 1. an in-process server: 2 workers, 16 MB resource-cache budget
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        cache_bytes: 16 << 20,
        ..ServeConfig::default()
    })?;
    let addr = server.local_addr().to_string();
    let handle = server.spawn();
    println!("server on {addr}");

    // 2. submit two experiments down one connection; both share a
    //    machine shape, so the second reuses the first one's prepared
    //    route plan (watch the `preparing` cache label)
    let mut client = Client::connect(&addr)?;
    let small = "n_wafers=2;torus=2x2x1;fpgas_per_wafer=4;concentrators_per_wafer=2;\
                 sources_per_fpga=8;duration_s=0.0002";
    for (tag, set) in [
        ("poisson", format!("{small};rate_hz=2e6")),
        ("poisson-hot", format!("{small};rate_hz=8e6")),
    ] {
        client.send(&Request::Submit(Submission {
            scenario: "traffic".to_string(),
            set,
            config: None,
            tag: tag.to_string(),
            // a generous wall-clock budget, as an example of per-job quotas
            quota: QuotaReq {
                max_wall_ms: Some(60_000),
                max_events: None,
            },
        }))?;
    }

    // 3. consume the streamed lifecycle until both jobs are done
    let mut done = 0;
    while done < 2 {
        match client.next_event()? {
            Event::Queued { job, tag } => println!("job {job} [{tag}] queued"),
            Event::Preparing { job, reused } => println!(
                "job {job} preparing ({})",
                if reused { "cache reuse" } else { "fresh prepare" }
            ),
            Event::Running { job, events_done } => {
                println!("job {job} running, {events_done} events done")
            }
            Event::Done { job, report } => {
                done += 1;
                // the report is the same JSON the batch CLI emits
                let delivered = report
                    .get("metrics")
                    .and_then(|m| m.as_arr())
                    .map(|rows| rows.len())
                    .unwrap_or(0);
                println!("job {job} done ({delivered} metrics)");
            }
            Event::Rejected { job, reason, .. } => {
                anyhow::bail!("job {job:?} rejected: {reason}")
            }
            other => println!("{other:?}"),
        }
    }

    // 4. ask for server-wide counters, then shut it down cleanly
    client.send(&Request::Stats)?;
    if let Event::Stats { body } = client.next_event()? {
        println!("server stats: {}", body.to_string());
    }
    client.send(&Request::Shutdown)?;
    handle.join()?;
    println!("server shut down cleanly");
    Ok(())
}
