//! Partitioned conservative PDES from the library API: run the same
//! traffic scenario at `domains = 1, 2, 4` under all three
//! synchronization protocols (windowed global minimum, per-neighbor
//! channel clocks, and barrier-free channel clocks), verify the reports
//! are byte-identical (domain count and sync protocol are perf knobs,
//! not physics — see docs/ARCHITECTURE.md §2.3), and print the
//! wall-clock scaling.
//!
//! Run: `cargo run --release --example pdes_domains`
//!
//! The CLI spelling of the same thing:
//! `bss-extoll run traffic --set "domains=4;sync=channel"` — every knob
//! is documented in docs/TUNING.md.

use std::time::Instant;

use bss_extoll::coordinator::scenario::find;
use bss_extoll::coordinator::ExperimentConfig;
use bss_extoll::extoll::network::pdes_lookahead;
use bss_extoll::extoll::torus::{DomainMap, TorusSpec};
use bss_extoll::sim::{SyncMode, Time};
use bss_extoll::util::bench::{eng, Table};
use bss_extoll::wafer::system::SystemConfig;

fn main() {
    // 4 wafers on a 2x2x2 torus: one concentrator node per torus node,
    // dense enough that each conservative window carries real work.
    let mut cfg = ExperimentConfig::default();
    cfg.system = SystemConfig {
        n_wafers: 4,
        torus: TorusSpec::new(2, 2, 2),
        fpgas_per_wafer: 8,
        concentrators_per_wafer: 2,
        ..SystemConfig::default()
    };
    cfg.workload.rate_hz = 2e7;
    cfg.workload.duration = Time::from_ms(1);

    let dm = DomainMap::new(cfg.system.torus, 4);
    let lookahead = pdes_lookahead(&dm, &cfg.system.nic).expect("inter-domain links");
    println!(
        "machine: {} wafers, {} torus nodes; lookahead at 4 domains: {} \
         (min cross-domain link latency)\n",
        cfg.system.n_wafers,
        cfg.system.torus.n_nodes(),
        lookahead
    );

    let scenario = find("traffic").expect("traffic registered");
    let mut table = Table::new(
        "PDES domain scaling — traffic scenario",
        &["sync", "domains", "des_events", "wall_s", "events/s", "speedup"],
    );
    let mut reference: Option<(String, f64)> = None;
    for (sync, domains) in [
        (SyncMode::Window, 1usize),
        (SyncMode::Window, 2),
        (SyncMode::Window, 4),
        (SyncMode::Channel, 2),
        (SyncMode::Channel, 4),
        (SyncMode::Free, 2),
        (SyncMode::Free, 4),
    ] {
        let mut c = cfg.clone();
        c.sync = sync;
        c.domains = domains;
        let t0 = Instant::now();
        let report = scenario.run(&c).expect("run failed");
        let wall = t0.elapsed().as_secs_f64();
        let events = report.get_count("des_events").expect("des_events");
        let json = report.to_json().pretty();
        let eps = events as f64 / wall;
        let speedup = if let Some((serial_json, serial_eps)) = &reference {
            assert_eq!(
                serial_json, &json,
                "report diverged at sync={} domains={domains} — determinism bug",
                sync.as_str()
            );
            eps / *serial_eps
        } else {
            1.0
        };
        if reference.is_none() {
            reference = Some((json, eps));
        }
        table.row(vec![
            // domains=1 takes the serial path regardless of sync mode;
            // label it like the bench artifact does to avoid implying a
            // windowed barrier ran
            if domains == 1 { "serial" } else { sync.as_str() }.to_string(),
            domains.to_string(),
            events.to_string(),
            format!("{wall:.3}"),
            eng(eps),
            format!("{speedup:.2}"),
        ]);
    }
    table.print();
    println!("\nreports byte-identical across sync modes and domain counts ✓");
}
