//! Ring-buffer host communication demo (paper §2.1, Fig. 2a).
//!
//! An FPGA streams trace data into a host ring buffer over the simulated
//! Extoll fabric using the RMA protocol: write pointer + space register on
//! the FPGA, notifications + batched SpaceFreed credits from the host
//! driver. Shows the credit-based flow control reacting to a slow
//! consumer, and compares against the per-message-handshake baseline the
//! scheme eliminates.
//!
//! Run: `cargo run --release --example ringbuffer_host`

use bss_extoll::extoll::baseline::{GbeConfig, GbeLink};
use bss_extoll::extoll::network::Fabric;
use bss_extoll::extoll::nic::{Nic, NicConfig};
use bss_extoll::extoll::packet::Packet;
use bss_extoll::extoll::torus::{NodeAddr, TorusSpec};
use bss_extoll::host::host::{ChannelConfig, Host, HostConfig};
use bss_extoll::host::stream::{StreamConfig, StreamSource, TIMER_PRODUCE};
use bss_extoll::msg::Msg;
use bss_extoll::sim::{Actor, ActorId, Ctx, Sim, Time};

fn main() {
    let total: u64 = 4 << 20; // 4 MiB
    println!("=== ring-buffer host communication (Fig. 2a) ===\n");

    for (label, ring, rate, consume) in [
        ("fast consumer", 1u64 << 16, 4e9, 0.0),
        ("slow consumer (100 MB/s)", 1 << 16, 4e9, 100e6),
        ("tiny ring (8 KiB)", 1 << 13, 4e9, 0.0),
    ] {
        let (mut sim, stream, host) = build(ring, rate, consume, total);
        sim.run(200_000_000);
        let s: &StreamSource = sim.get(stream);
        let h: &Host = sim.get(host);
        println!("{label}:");
        println!("  ring size:        {} KiB", ring >> 10);
        println!("  bytes consumed:   {} ({} notifications)", h.stats.bytes_consumed, h.stats.notifications);
        println!("  credits sent:     {}", h.stats.credits_sent);
        println!(
            "  producer stalls:  {} episodes, {} total",
            s.stats.stall_episodes, s.stats.stall_time
        );
        println!(
            "  achieved:         {:.2} Gbit/s over {}",
            h.stats.bytes_consumed as f64 * 8.0 / sim.now.secs_f64() / 1e9,
            sim.now
        );
        println!(
            "  data latency p50: {:.1} us\n",
            h.stats.data_latency_ps.p50() as f64 / 1e6
        );
        assert_eq!(h.stats.bytes_consumed, total, "data loss!");
    }

    // ---- handshake baseline over GbE (what the ring buffer replaces) ----
    println!("--- baseline: per-message handshake over GbE ---");
    for handshake in [false, true] {
        let cfg = GbeConfig {
            handshake,
            ..GbeConfig::default()
        };
        let mut sim: Sim<Msg> = Sim::new();
        let link = sim.add(GbeLink::new(cfg));
        let sink = sim.add(CountSink { bytes: 0 });
        sim.get_mut::<GbeLink>(link).attach_sink(sink);
        let chunk = 1024u32;
        let n = 2048u64;
        for i in 0..n {
            sim.schedule(
                Time::ZERO,
                link,
                Msg::Inject(Packet::raw_gbe(NodeAddr(0), NodeAddr(1), chunk, Time::ZERO, i)),
            );
        }
        sim.run(100_000_000);
        let b = sim.get::<CountSink>(sink).bytes;
        println!(
            "  {}: {:.3} Gbit/s ({} KiB in {})",
            if handshake { "handshake " } else { "streaming " },
            b as f64 * 8.0 / sim.now.secs_f64() / 1e9,
            b >> 10,
            sim.now
        );
    }
    println!("\nringbuffer_host OK");
}

struct CountSink {
    bytes: u64,
}

impl Actor<Msg> for CountSink {
    fn handle(&mut self, msg: Msg, _ctx: &mut Ctx<'_, Msg>) {
        if let Msg::Deliver(p) = msg {
            self.bytes += p.payload_bytes as u64;
        }
    }
}

fn build(
    ring: u64,
    rate: f64,
    consume: f64,
    total: u64,
) -> (Sim<Msg>, ActorId, ActorId) {
    let mut sim: Sim<Msg> = Sim::new();
    let fabric = Fabric::build(&mut sim, TorusSpec::new(2, 1, 1), NicConfig::default());
    let stream = sim.add(StreamSource::new(StreamConfig {
        node: NodeAddr(0),
        host_node: NodeAddr(1),
        ring_size: ring,
        rate_bps: rate,
        total_bytes: total,
        ..StreamConfig::default()
    }));
    let host = sim.add(Host::new(HostConfig {
        node: NodeAddr(1),
        consume_rate: consume,
        ..HostConfig::default()
    }));
    {
        let h = sim.get_mut::<Host>(host);
        h.attach_nic(fabric.nics[1]);
        h.add_channel(ChannelConfig {
            id: 1,
            nla_base: 0x10000,
            ring_size: ring,
            producer_node: NodeAddr(0),
            credit_batch: ring / 4,
        });
    }
    sim.get_mut::<StreamSource>(stream).attach_nic(fabric.nics[0]);
    sim.get_mut::<Nic>(fabric.nics[0]).attach_local(stream);
    sim.get_mut::<Nic>(fabric.nics[1]).attach_local(host);
    sim.schedule(Time::ZERO, stream, Msg::Timer(TIMER_PRODUCE));
    (sim, stream, host)
}
