.PHONY: artifacts test bench bench-json serve-smoke clean

# AOT-lower the JAX/Pallas shard models into artifacts/ (HLO + manifest).
# The rust runtime consumes the manifests; see rust/src/runtime/client.rs.
artifacts:
	cd python && python3 -m compile.aot --suite --out ../artifacts

# Tier-1 verification.
test:
	cargo build --release && cargo test -q

bench:
	BSS_BENCH_FAST=1 cargo bench

# Perf-trajectory artifact: heap-vs-wheel event engine, sweep scaling,
# PDES domain scaling, PDES sync-protocol scaling (window vs channel
# clocks vs barrier-free), sweep resource cache, packet pooling, the
# degraded-fabric fault sweep, the link-reliability sweep, the
# service-mode serve_throughput round and the rack_scaling curve
# (microcircuit_rack at 4/8/20 wafers: fabric-reuse rewind vs cold
# rebuild, events/s, resident bytes, bytes/neuron). Writes
# BENCH_PR10.json at the repo root (see PERF.md). Honors
# BSS_BENCH_FAST=1 (CI smoke); override the output with BSS_BENCH_JSON.
# Committed BENCH_PR*.json placeholders are policed by
# scripts/validate_bench.py (CI bench-smoke).
BSS_BENCH_JSON ?= BENCH_PR10.json
bench-json:
	BSS_BENCH_JSON=$(BSS_BENCH_JSON) cargo bench --bench bench_events

# Service-mode smoke: bind an ephemeral port, run one in-process loadgen
# round (40 submissions, verified byte-identical to the batch path),
# assert completion and a clean shutdown. Wired into CI.
serve-smoke:
	cargo run --release -- serve --smoke 40

clean:
	cargo clean
