.PHONY: artifacts test bench clean

# AOT-lower the JAX/Pallas shard models into artifacts/ (HLO + manifest).
# The rust runtime consumes the manifests; see rust/src/runtime/client.rs.
artifacts:
	cd python && python3 -m compile.aot --suite --out ../artifacts

# Tier-1 verification.
test:
	cargo build --release && cargo test -q

bench:
	BSS_BENCH_FAST=1 cargo bench

clean:
	cargo clean
