#!/usr/bin/env python3
"""Bench-artifact policy + shape validation (CI `bench-smoke`).

Two subcommands:

  committed <file...>   Police the *committed* BENCH_PR*.json files: a
                        placeholder (any file carrying a
                        "pending_regeneration" note) FAILS the build
                        unless it also carries an explicit "waiver"
                        string saying why regeneration was impossible.
                        Waived placeholders print a loud warning so the
                        debt stays visible on every run instead of
                        rotting silently.

  artifact <file>       Structural validation of a freshly regenerated
                        artifact (the fast-mode `make bench-json` output):
                        every section present, determinism bits true,
                        cache counters exact. Placeholders are rejected
                        outright here — a regenerated artifact can never
                        be pending.

Exit code 0 = pass, 1 = policy or shape violation.
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"::error::{msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: unreadable bench artifact: {e}")


def check_committed(paths):
    """Placeholders must carry an explicit waiver; real artifacts pass."""
    if not paths:
        fail("no committed bench artifacts to check (expected BENCH_PR*.json)")
    waived = 0
    for path in paths:
        j = load(path)
        if "pending_regeneration" not in j:
            print(f"{path}: real artifact (fast={j.get('fast')}) — ok")
            continue
        waiver = j.get("waiver")
        if not isinstance(waiver, str) or not waiver.strip():
            fail(
                f"{path} is a pending_regeneration placeholder with no "
                f'explicit "waiver" — regenerate it with `make bench-json` '
                f"on a host with a Rust toolchain, or record why that is "
                f'impossible in a "waiver" field'
            )
        waived += 1
        print(f"::warning::{path}: placeholder WAIVED — {waiver.strip()}")
    if waived:
        print(
            f"{waived} placeholder(s) waived; full-mode numbers are still "
            f"owed (see PERF.md)"
        )


def check_fault_sweep(j):
    """Shape of the PR 6 degraded-fabric section: deliverability starts
    at exactly 1.0 on the healthy fabric and is monotone non-increasing
    in the failed-cable fraction; the faulted cross-domain identity bit
    must hold."""
    f = j["fault_sweep"]
    assert f["deterministic_across_domains"] is True
    runs = f["runs"]
    assert len(runs) >= 3, f"fault_sweep needs >= 3 points, got {len(runs)}"
    assert runs[0]["fault"] == "none", runs[0]
    assert runs[0]["failed_cables"] == 0, runs[0]
    assert runs[0]["deliverability"] == 1.0, runs[0]
    assert runs[0]["hop_inflation"] == 1.0, runs[0]
    prev_cables, prev_deliv = -1, float("inf")
    for r in runs:
        assert 0.0 <= r["deliverability"] <= 1.0, r
        assert r["hop_inflation"] >= 1.0, r
        assert r["failed_cables"] > prev_cables, (
            f"failed-cable counts must grow along the sweep: {runs}"
        )
        assert r["deliverability"] <= prev_deliv, (
            f"deliverability must be monotone non-increasing: {runs}"
        )
        prev_cables, prev_deliv = r["failed_cables"], r["deliverability"]


def check_reliability_sweep(j):
    """Shape of the PR 7 link-reliability section: with reliability=link
    deliverability is exactly 1.0 at every swept loss rate (zero residual
    loss below the retry limit) and never below the off curve at the same
    fault spec; with reliability=off no recovery machinery runs; and the
    cross-domain identity bit must hold with retransmission timers live."""
    s = j["reliability_sweep"]
    assert s["deterministic_across_domains"] is True
    assert s["link_vs_off_at_zero_loss"] > 0, s
    runs = s["runs"]
    assert len(runs) >= 4, f"reliability_sweep needs >= 4 points, got {len(runs)}"
    off = {r["fault"]: r for r in runs if r["reliability"] == "off"}
    link = {r["fault"]: r for r in runs if r["reliability"] == "link"}
    assert off and link, f"need both off and link points: {runs}"
    assert set(off) == set(link), f"off/link fault specs must pair up: {runs}"
    for spec, r in link.items():
        assert r["deliverability"] == 1.0, (
            f"reliability=link must deliver everything at {spec}: {r}"
        )
        assert r["residual_loss_events"] == 0, (
            f"residual loss below the retry limit at {spec}: {r}"
        )
        assert r["deliverability"] >= off[spec]["deliverability"], (
            f"link below the off curve at {spec}"
        )
        # a lossy point must show the machinery actually working
        if r["crc_failures"] > 0:
            assert r["retransmissions"] > 0, f"CRC failures but no retx at {spec}: {r}"
    for spec, r in off.items():
        assert r["retransmissions"] == 0, f"retransmissions with the layer off: {r}"
    lossy_off = [r for r in off.values() if r["fault"] != "none"]
    assert any(r["deliverability"] < 1.0 for r in lossy_off), (
        f"the off curve must show the loss the layer repairs: {runs}"
    )


def check_serve_throughput(j):
    """Shape of the PR 9 service-mode section: every submission of the
    loadgen round completed, served reports were verified byte-identical
    to the batch `run` path, the cross-submission cache actually shared
    (prepared strictly below the submission count), and the LRU byte
    accounting never exceeded the configured budget. Full-mode artifacts
    must carry the 100+-submission acceptance round; fast-mode CI rounds
    may be smaller but never trivial."""
    s = j["serve_throughput"]
    floor = 100 if j.get("fast") is False else 20
    assert s["submitted"] >= floor, (
        f"serve_throughput needs >= {floor} submissions, got {s['submitted']}"
    )
    assert s["completed"] == s["submitted"], (
        f"{s['submitted'] - s['completed']} submissions did not complete: {s}"
    )
    assert s["rejected"] == 0 and s["cancelled"] == 0, s
    assert s["verified"] > 0, "serve_throughput ran without verification"
    assert s["mismatches"] == 0, f"served reports diverged from the batch path: {s}"
    assert s["reports_byte_identical"] is True, s
    assert s["subs_per_s"] > 0, s
    assert s["turnaround_p95_us"] >= s["turnaround_p50_us"] > 0, s
    cache = s["cache"]
    assert cache["prepared"] + cache["reused"] == s["submitted"], cache
    assert cache["prepared"] < s["submitted"], (
        f"cross-submission cache never shared a prepare: {cache}"
    )
    budget = s["cache_budget_bytes"]
    if budget > 0:
        assert cache["resident_bytes"] <= budget, (
            f"cache resident bytes exceed the byte budget: {cache} vs {budget}"
        )


def check_rack_scaling(j):
    """Shape of the PR 10 rack-scaling section: the `microcircuit_rack`
    scenario at growing wafer counts (4/8/20 — at least three points up
    to the paper's 20-wafer rack), each with positive throughput and
    resident-byte accounting, monotone resident bytes in the machine
    size, and the fabric-rewind-vs-cold-rebuild byte-identity bit set.
    Checked unconditionally (fast and full mode)."""
    r = j["rack_scaling"]
    assert r["deterministic_reuse_vs_rebuild"] is True
    runs = r["runs"]
    assert len(runs) >= 3, f"rack_scaling needs >= 3 wafer counts, got {len(runs)}"
    prev_wafers, prev_resident = 0, 0
    for run in runs:
        assert run["wafers"] > prev_wafers, f"wafer counts must grow: {runs}"
        assert run["n_fpgas"] >= run["wafers"], run
        assert run["events_per_s"] > 0, run
        assert run["resident_bytes"] >= prev_resident, (
            f"prepared-plan resident bytes must grow with the machine: {runs}"
        )
        assert run["bytes_per_neuron"] > 0, run
        assert run["reuse_speedup"] > 0, run
        prev_wafers, prev_resident = run["wafers"], run["resident_bytes"]
    assert runs[-1]["wafers"] >= 20, (
        f"rack_scaling must reach the 20-wafer rack: {runs[-1]}"
    )


def check_artifact(path):
    """Shape checks for a regenerated BENCH_PR10 artifact."""
    j = load(path)
    if "pending_regeneration" in j:
        fail(f"{path}: regenerated artifact is still a placeholder")
    assert j["schema"] == "bss-extoll-bench/1", j.get("schema")
    assert j["artifact"] == "BENCH_PR10", j.get("artifact")
    assert j["queue_transit"]["results"], "no queue benches recorded"
    assert not j["queue_transit"]["skipped"], j["queue_transit"]["skipped"]
    assert j["sweep_scaling"]["deterministic_across_jobs"] is True

    p = j["pdes_domain_scaling"]
    assert p["deterministic_across_domains"] is True
    assert len(p["runs"]) == 3, p["runs"]

    s = j["pdes_sync_scaling"]
    assert s["deterministic_across_modes"] is True
    # serial baseline + {window,channel,free} x {2,4,8}
    assert len(s["runs"]) == 10, s["runs"]
    modes = {(r["sync"], r["domains"]) for r in s["runs"]}
    for domains in (2, 4, 8):
        for sync in ("window", "channel", "free"):
            assert (sync, domains) in modes, f"missing {sync} run at {domains}"
    ratio = s["channel_vs_window_at_4_domains"]
    assert ratio > 0, s
    assert s["free_vs_channel_at_4_domains"] > 0, s
    # The PR 5 acceptance bar: channel clocks must not lose to the
    # windowed protocol at domains=4. Only enforced for full-mode
    # artifacts — fast-mode CI runners are 2-core and oversubscribed, so
    # their wall-clock ratios are noise. An explained regression is
    # recorded as a "regression_note" (mirrored in PERF.md) and demotes
    # the failure to a loud warning.
    if j.get("fast") is False and ratio < 1.0:
        note = s.get("regression_note")
        if isinstance(note, str) and note.strip():
            print(f"::warning::channel_vs_window_at_4_domains = {ratio:.2f} "
                  f"< 1.0 — explained regression: {note.strip()}")
        else:
            raise AssertionError(
                f"channel clocks slower than windowed at 4 domains "
                f"({ratio:.2f}x < 1.0) with no regression_note/PERF.md "
                f"explanation"
            )

    c = j["sweep_cache"]
    for scn in ("traffic", "microcircuit"):
        assert scn in c, f"sweep_cache missing {scn} section"
        assert c[scn]["n_points"] >= 4, c[scn]
        assert c[scn]["cache_misses"] == 1, f"{scn}: prepare ran more than once"
        assert c[scn]["cache_hits"] == c[scn]["n_points"] - 1, c[scn]

    pp = j["packet_pooling"]
    assert pp["deterministic_pool_on_off"] is True
    assert pp["buffers_recycled"] > 0, "pool never recycled a buffer"

    check_fault_sweep(j)
    worst_deliv = min(r["deliverability"] for r in j["fault_sweep"]["runs"])

    check_reliability_sweep(j)
    rel = j["reliability_sweep"]

    check_serve_throughput(j)
    serve = j["serve_throughput"]

    check_rack_scaling(j)
    rack = j["rack_scaling"]["runs"][-1]

    print(
        f"{path} ok:",
        f"wheel_vs_heap={j['traffic_event_loop']['wheel_vs_heap_speedup']:.2f}x",
        f"pdes={p['multi_domain_vs_serial_speedup']:.2f}x",
        f"channel_vs_window@4={s['channel_vs_window_at_4_domains']:.2f}x",
        f"free_vs_channel@4={s['free_vs_channel_at_4_domains']:.2f}x",
        f"cache(mc)={c['microcircuit']['speedup']:.2f}x",
        f"pool={pp['speedup']:.2f}x",
        f"fault_deliv_min={worst_deliv:.3f}",
        f"link@loss0={rel['link_vs_off_at_zero_loss']:.2f}x",
        f"serve={serve['subs_per_s']:.1f} subs/s "
        f"(p50={serve['turnaround_p50_us']}us, "
        f"cache {serve['cache']['prepared']}/{serve['cache']['reused']})",
        f"rack@{rack['wafers']}w={rack['events_per_s']:.3g} ev/s "
        f"({rack['bytes_per_neuron']:.1f} B/neuron, "
        f"reuse {rack['reuse_speedup']:.2f}x)",
    )


def main():
    if len(sys.argv) < 3:
        fail(f"usage: {sys.argv[0]} committed <file...> | artifact <file>")
    cmd = sys.argv[1]
    if cmd == "committed":
        check_committed(sys.argv[2:])
    elif cmd == "artifact":
        check_artifact(sys.argv[2])
    else:
        fail(f"unknown subcommand '{cmd}'")


if __name__ == "__main__":
    try:
        main()
    except AssertionError as e:
        fail(f"bench artifact validation failed: {e}")
    except KeyError as e:
        fail(f"bench artifact missing section/field: {e}")
