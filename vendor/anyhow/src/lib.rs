//! Vendored minimal re-implementation of the `anyhow` API surface used by
//! this repository (the build is fully offline, so crates.io is not
//! available). Provides [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics follow upstream `anyhow` where the repository relies on them:
//!
//! - `{e}` displays the outermost context only; `{e:#}` displays the whole
//!   chain as `outer: inner: root`.
//! - `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what allows the blanket `From<E: std::error::Error>` conversion
//!   (and hence `?` on arbitrary error types) to coexist with the identity
//!   `From<Error>` impl.
//! - [`Context`] is implemented for both `Result` and `Option`.
//! - An `Error` built from a typed error value ([`Error::new`] or `?`)
//!   keeps that value, and [`Error::downcast_ref`] reaches it through
//!   any number of `context` layers — the mechanism service mode uses
//!   to tell a quota `Interrupt` apart from a genuine failure. Errors
//!   built from plain messages carry no payload and downcast to
//!   nothing.

use std::any::Any;
use std::fmt;

/// A context-carrying error: an ordered chain of messages, root cause
/// first, outermost context last.
pub struct Error {
    /// `frames[0]` is the root cause; later entries wrap earlier ones.
    frames: Vec<String>,
    /// The typed root cause, when the error was built from one.
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            frames: vec![message.to_string()],
            payload: None,
        }
    }

    /// Create an error from a typed error value, keeping the value so
    /// [`downcast_ref`](Error::downcast_ref) can recover it later.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        // Capture the source chain, root cause first.
        let mut messages = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            messages.push(s.to_string());
            source = s.source();
        }
        messages.reverse();
        Error {
            frames: messages,
            payload: Some(Box::new(error)),
        }
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.push(context.to_string());
        self
    }

    /// The typed root cause, if this error was built from one
    /// ([`Error::new`] or the `?` conversion) of that exact type.
    /// Context layers do not hide it.
    pub fn downcast_ref<E: Any>(&self) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }

    /// Iterate the chain from the outermost context to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().rev().map(|s| s.as_str())
    }

    /// The root cause message.
    pub fn root_cause(&self) -> &str {
        self.frames.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut chain = self.chain();
        match chain.next() {
            Some(outer) => write!(f, "{outer}")?,
            None => write!(f, "unknown error")?,
        }
        if f.alternate() {
            for frame in chain {
                write!(f, ": {frame}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut chain = self.chain();
        match chain.next() {
            Some(outer) => write!(f, "{outer}")?,
            None => write!(f, "unknown error")?,
        }
        let rest: Vec<&str> = chain.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_outer_only_alternate_full_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        assert!(f(11).unwrap_err().to_string().contains("11"));
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn downcast_ref_reaches_the_typed_root_cause() {
        let e = Error::from(io_err());
        assert_eq!(
            e.downcast_ref::<std::io::Error>().unwrap().kind(),
            std::io::ErrorKind::NotFound
        );
        // context layers don't hide the payload
        let e = e.context("outer");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.downcast_ref::<fmt::Error>().is_none());
        // message-built errors carry no payload
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: file missing");
    }
}
